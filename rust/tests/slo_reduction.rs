//! Differential property tests for the SLO-tier subsystem: the classed
//! machinery must be a strict superset of the single-class paper model.
//!
//! * **Generator reduction** — `ClassMixGen` with zero/one default
//!   class produces a request stream bit-identical to `LmsysGen` under
//!   the same seed (same RNG draws in the same order).
//! * **Scheduler reduction** — `PrioritySf` with uniform ranks is
//!   outcome-bit-identical to `McSf`, and untiered `EdfThreshold` to
//!   `FcfsThreshold`, across the same instance corpus style as
//!   `tests/incremental_diff.rs` / `tests/cluster_reduction.rs`.
//! * **Classed sanity** — a tiered run partitions per-class volumes,
//!   reports goodput in [0, 1], keeps TTFT ≤ latency, and is
//!   bit-reproducible given the seed.

use kvsched::core::{ClassSet, Instance, Request};
use kvsched::metrics::SimOutcome;
use kvsched::perf::UnitTime;
use kvsched::predictor::Predictor;
use kvsched::prelude::*;
use kvsched::sim::engine::run;
use kvsched::sim::SimConfig;
use kvsched::util::prop::{forall_cases, usize_in};
use kvsched::workload::{ClassMixGen, LmsysGen};

fn cfg() -> SimConfig {
    SimConfig {
        max_rounds: 10_000,
        stall_rounds: 1_500,
        record_series: true,
        incremental: true,
        ..SimConfig::default()
    }
}

/// Everything except the policy name must match bit-for-bit.
fn assert_outcomes_identical(a: &SimOutcome, b: &SimOutcome, ctx: &str) {
    assert_eq!(a.finished, b.finished, "{ctx}: finished");
    assert_eq!(a.rounds, b.rounds, "{ctx}: rounds");
    assert_eq!(a.assigned, b.assigned, "{ctx}: assigned");
    assert_eq!(a.peak_mem, b.peak_mem, "{ctx}: peak_mem");
    assert_eq!(a.overflow_events, b.overflow_events, "{ctx}: overflows");
    assert_eq!(a.evicted_requests, b.evicted_requests, "{ctx}: evictions");
    assert_eq!(a.per_request, b.per_request, "{ctx}: per-request records");
    assert_eq!(a.mem_series, b.mem_series, "{ctx}: memory series");
    assert_eq!(a.tokens_series, b.tokens_series, "{ctx}: token series");
    assert_eq!(
        a.total_latency().to_bits(),
        b.total_latency().to_bits(),
        "{ctx}: total latency bits"
    );
}

fn random_instance(seed: u64) -> Instance {
    let mut rng = Rng::new(seed);
    let m = rng.i64_range(8, 50) as u64;
    let n = rng.usize_range(1, 30);
    let reqs: Vec<Request> = (0..n)
        .map(|i| {
            let s = rng.i64_range(1, 5) as u64;
            let o = rng.i64_range(1, (m - s).min(14) as i64) as u64;
            let a = rng.i64_range(0, 8) as f64;
            Request::new(i, a, s, o)
        })
        .collect();
    Instance::new(m, reqs)
}

/// Generator half of the acceptance criterion: a single default class
/// consumes exactly the same RNG stream as the classless base generator.
#[test]
fn single_class_generator_is_bit_identical_to_base() {
    for (label, classes) in [
        ("empty", ClassSet::default()),
        ("one-default", ClassSet::parse("default:1.0").unwrap()),
    ] {
        let gen = ClassMixGen::new(classes, 500);
        for seed in [0u64, 1, 7, 42] {
            let a = gen.instance(250, 20.0, 500, &mut Rng::new(seed));
            let b = LmsysGen::new(500).instance(250, 20.0, 500, &mut Rng::new(seed));
            assert_eq!(a.requests, b.requests, "{label} seed={seed}");
            assert_eq!(a.m, b.m, "{label} seed={seed}");
            assert!(a.requests.iter().all(|r| r.class == 0), "{label}");
        }
    }
}

/// Scheduler half: the classed instance with a default SLO runs through
/// the priority scheduler exactly like MC-SF runs the classless trace.
#[test]
fn single_class_slo_run_matches_classless_run() {
    let classes = ClassSet::parse("default:1.0").unwrap();
    for seed in [3u64, 9] {
        let classed =
            ClassMixGen::new(classes.clone(), 400).instance(150, 15.0, 400, &mut Rng::new(seed));
        let plain = LmsysGen::new(400).instance(150, 15.0, 400, &mut Rng::new(seed));
        let a = run(
            &classed,
            &mut PrioritySf::new(&classes, 0.0),
            &Predictor::exact(),
            &UnitTime,
            5,
            cfg(),
        )
        .unwrap();
        let b = run(&plain, &mut McSf::default(), &Predictor::exact(), &UnitTime, 5, cfg())
            .unwrap();
        assert_outcomes_identical(&a, &b, &format!("seed={seed}"));
        // The classed outcome additionally carries the class table.
        assert_eq!(a.classes, classes);
        assert!(b.classes.is_empty());
    }
}

/// P-MC-SF with uniform ranks ≡ MC-SF on every instance, both engine
/// paths, exact predictions (no overflow ⇒ the clearing policies never
/// diverge).
#[test]
fn uniform_priority_equals_mcsf_on_random_instances() {
    forall_cases(0x510, 80, usize_in(0, u32::MAX as usize), |&seed| {
        let inst = random_instance(seed as u64);
        for incremental in [true, false] {
            let c = SimConfig {
                incremental,
                ..cfg()
            };
            let a = run(
                &inst,
                &mut PrioritySf::uniform(),
                &Predictor::exact(),
                &UnitTime,
                9,
                c,
            )
            .map_err(|e| format!("priority failed: {e}"))?;
            let b = run(&inst, &mut McSf::default(), &Predictor::exact(), &UnitTime, 9, c)
                .map_err(|e| format!("mcsf failed: {e}"))?;
            assert_outcomes_identical(
                &a,
                &b,
                &format!("seed={seed:#x} incremental={incremental}"),
            );
        }
        Ok(())
    });
}

/// Untiered EDF ≡ FCFS (infinite deadlines make the deadline order
/// collapse to arrival order), including under noisy predictions — both
/// clear everything on overflow.
#[test]
fn untiered_edf_equals_fcfs_on_random_instances() {
    forall_cases(0xEDF, 60, usize_in(0, u32::MAX as usize), |&seed| {
        let inst = random_instance(seed as u64);
        for (pname, pred) in [
            ("exact", Predictor::exact()),
            ("noisy", Predictor::uniform_noise(0.5, 11)),
        ] {
            let a = run(
                &inst,
                &mut EdfThreshold::untiered(0.9),
                &pred,
                &UnitTime,
                9,
                cfg(),
            )
            .map_err(|e| format!("edf failed: {e}"))?;
            let b = run(
                &inst,
                &mut FcfsThreshold { threshold: 0.9 },
                &pred,
                &UnitTime,
                9,
                cfg(),
            )
            .map_err(|e| format!("fcfs failed: {e}"))?;
            assert_outcomes_identical(&a, &b, &format!("seed={seed:#x} pred={pname}"));
        }
        Ok(())
    });
}

/// A tiered end-to-end run: conservation per class, sane SLO metrics,
/// TTFT ordering, and bit-reproducibility.
#[test]
fn tiered_run_partitions_and_scores_sanely() {
    let classes = ClassSet::parse("interactive:0.7,batch:0.3").unwrap();
    let inst = ClassMixGen::new(classes.clone(), 2000).instance(250, 20.0, 2000, &mut Rng::new(21));
    assert_eq!(inst.classes, classes);
    let run_once = |spec: &str| {
        let mut sched = kvsched::sched::by_name_classed(spec, &classes).unwrap();
        let c = SimConfig {
            max_rounds: 100_000,
            stall_rounds: 20_000,
            ..cfg()
        };
        run(&inst, sched.as_mut(), &Predictor::exact(), &UnitTime, 13, c).unwrap()
    };
    // The Eq-(5) forward-check policies complete every request under
    // exact predictions; the threshold baselines (fcfs/edf) can
    // deterministically livelock on heavy batch tails, so they are
    // exercised by the reduction tests above instead.
    for spec in ["priority", "mcsf"] {
        let out = run_once(spec);
        assert!(out.finished, "{spec}");
        assert_eq!(out.per_request.len(), inst.n(), "{spec}");
        // Assigned partitions by class and matches the instance tags.
        let tagged = |c: usize| inst.requests.iter().filter(|r| r.class == c).count();
        assert_eq!(out.class_assigned(0), tagged(0), "{spec}");
        assert_eq!(out.class_assigned(1), tagged(1), "{spec}");
        // Goodput is a probability; per-class goodputs too.
        for g in [out.goodput(), out.class_goodput(0), out.class_goodput(1)] {
            assert!((0.0..=1.0).contains(&g), "{spec}: goodput {g}");
        }
        // TTFT: positive, at most the e2e latency, first token after
        // the (first) start of service.
        for r in &out.per_request {
            assert!(r.ttft() > 0.0, "{spec}: ttft {}", r.ttft());
            assert!(r.ttft() <= r.latency() + 1e-12, "{spec}");
        }
        // Deterministic given the seed.
        let again = run_once(spec);
        assert_eq!(out.per_request, again.per_request, "{spec}");
        assert_eq!(
            out.total_latency().to_bits(),
            again.total_latency().to_bits(),
            "{spec}"
        );
    }
}

/// Classed instances survive the JSON trace roundtrip with tags and SLO
/// table intact, and replay to the identical outcome.
#[test]
fn classed_trace_roundtrip_replays_identically() {
    let classes = ClassSet::parse("interactive:0.6,batch:0.4").unwrap();
    let inst = ClassMixGen::new(classes.clone(), 800).instance(120, 15.0, 800, &mut Rng::new(4));
    let back = Instance::from_json(&inst.to_json()).unwrap();
    assert_eq!(back, inst);
    let a = run(
        &inst,
        &mut PrioritySf::new(&classes, 0.0),
        &Predictor::exact(),
        &UnitTime,
        2,
        cfg(),
    )
    .unwrap();
    let b = run(
        &back,
        &mut PrioritySf::new(&classes, 0.0),
        &Predictor::exact(),
        &UnitTime,
        2,
        cfg(),
    )
    .unwrap();
    assert_eq!(a.per_request, b.per_request);
    assert_eq!(a.total_latency().to_bits(), b.total_latency().to_bits());
}
