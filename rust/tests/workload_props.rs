//! Workload generator properties: determinism (same seed ⇒ identical
//! `Instance`), per-request feasibility, and arrival-sortedness, for the
//! §5.1 synthetic arrival models and the LMSYS-calibrated generator.

use kvsched::core::Instance;
use kvsched::util::rng::Rng;
use kvsched::workload::{scale_arrival_rate, synthetic, LmsysGen};

fn assert_well_formed(inst: &Instance, ctx: &str) {
    assert!(inst.n() > 0, "{ctx}: empty instance");
    assert!(inst.is_feasible(), "{ctx}: generated an infeasible request");
    assert!(
        inst.requests.windows(2).all(|w| w[0].arrival <= w[1].arrival),
        "{ctx}: arrivals not sorted"
    );
    for (i, r) in inst.requests.iter().enumerate() {
        assert_eq!(r.id, i, "{ctx}: ids not dense in arrival order");
        assert!(r.prompt_len >= 1 && r.output_len >= 1, "{ctx}: empty request");
    }
}

#[test]
fn arrival_model_1_deterministic_feasible_sorted() {
    for seed in 0..25u64 {
        let a = synthetic::arrival_model_1(&mut Rng::new(seed));
        let b = synthetic::arrival_model_1(&mut Rng::new(seed));
        assert_eq!(a, b, "seed {seed}: same seed must give identical instances");
        assert_well_formed(&a, &format!("model1 seed={seed}"));
    }
}

#[test]
fn arrival_model_2_deterministic_feasible_sorted() {
    for seed in 0..25u64 {
        let a = synthetic::arrival_model_2(&mut Rng::new(seed));
        let b = synthetic::arrival_model_2(&mut Rng::new(seed));
        assert_eq!(a, b, "seed {seed}: same seed must give identical instances");
        assert_well_formed(&a, &format!("model2 seed={seed}"));
    }
}

#[test]
fn lmsys_generator_deterministic_feasible_sorted() {
    let gen = LmsysGen::default();
    for seed in 0..10u64 {
        let a = gen.instance(400, 50.0, gen.max_peak, &mut Rng::new(seed));
        let b = gen.instance(400, 50.0, gen.max_peak, &mut Rng::new(seed));
        assert_eq!(a, b, "seed {seed}: same seed must give identical instances");
        assert_well_formed(&a, &format!("lmsys seed={seed}"));
    }
}

#[test]
fn adversarial_thm41_feasible_sorted() {
    for m in [16u64, 64, 256] {
        let inst = synthetic::adversarial_thm41(m, 0);
        assert_well_formed(&inst, &format!("thm41 m={m}"));
    }
}

#[test]
fn rate_scaling_preserves_well_formedness() {
    // The cluster layer's λ × N scaling must hand the fleet engine an
    // instance with the same guarantees the generators provide.
    let gen = LmsysGen::default();
    let inst = gen.instance(300, 10.0, gen.max_peak, &mut Rng::new(3));
    for factor in [2.0, 4.0, 8.0] {
        let scaled = scale_arrival_rate(&inst, factor);
        assert_eq!(scaled.n(), inst.n());
        assert_well_formed(&scaled, &format!("scaled ×{factor}"));
    }
}