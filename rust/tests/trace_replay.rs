//! Differential test for the record/replay subsystem: a trace recorded
//! from any engine run must (a) survive the text serialization
//! round-trip exactly and (b) replay to a **bit-identical**
//! `SimOutcome` / `FleetOutcome` — same admit order, same per-request
//! records, same memory/overflow/eviction counters and series, same
//! round count — over the same instance corpus as
//! `tests/incremental_diff.rs`, on both the incremental and snapshot
//! engine paths. Tampered traces must fail with the first diverging
//! event, and the committed golden traces under `golden/` must keep
//! replaying clean (CI diffs them against fresh recordings).

use kvsched::core::{ClassSet, DisaggSpec, Instance, Request};
use kvsched::flow::FlowSpec;
use kvsched::metrics::SimOutcome;
use kvsched::perf::UnitTime;
use kvsched::predictor::Predictor;
use kvsched::sim::{EngineKind, SimConfig};
use kvsched::trace::{
    record_fleet, record_fleet_disagg, record_fleet_flow, record_sim, record_sim_flow,
    replay_fleet, replay_sim, ReplayError, Trace, TraceEvent,
};
use kvsched::util::prop::{forall_cases, usize_in};
use kvsched::util::rng::Rng;
use kvsched::workload::{overload, synthetic, ClassMixGen};
use std::path::PathBuf;

/// Incremental implementations plus snapshot-only baselines — same mix
/// as the cluster_reduction corpus.
const SPECS: [&str; 4] = [
    "mcsf",
    "mc-benchmark",
    "protect:alpha=0.1,beta=0.5",
    "fcfs:threshold=0.9",
];

fn cfg(incremental: bool) -> SimConfig {
    SimConfig {
        // Bounded caps so clearing livelocks terminate quickly; record
        // and replay share the caps, so truncated runs must match too.
        max_rounds: 10_000,
        stall_rounds: 1_500,
        record_series: true,
        incremental,
        ..SimConfig::default()
    }
}

fn assert_identical(a: &SimOutcome, b: &SimOutcome, ctx: &str) {
    assert_eq!(a.algo, b.algo, "{ctx}: algo");
    assert_eq!(a.assigned, b.assigned, "{ctx}: assigned");
    assert_eq!(a.finished, b.finished, "{ctx}: finished");
    assert_eq!(a.rounds, b.rounds, "{ctx}: rounds");
    assert_eq!(a.peak_mem, b.peak_mem, "{ctx}: peak_mem");
    assert_eq!(a.overflow_events, b.overflow_events, "{ctx}: overflows");
    assert_eq!(a.evicted_requests, b.evicted_requests, "{ctx}: evictions");
    assert_eq!(a.per_request, b.per_request, "{ctx}: per-request records");
    assert_eq!(a.mem_series, b.mem_series, "{ctx}: memory series");
    assert_eq!(a.tokens_series, b.tokens_series, "{ctx}: token series");
    assert_eq!(
        a.total_latency().to_bits(),
        b.total_latency().to_bits(),
        "{ctx}: total latency bits"
    );
}

/// Record on both engine paths, replay, and push the trace through the
/// text format once — the replayed outcome must match bit-for-bit in
/// every combination.
fn check_roundtrip(inst: &Instance, case: &str) -> Result<(), String> {
    for spec in SPECS {
        for (pname, pred) in [
            ("exact", Predictor::exact()),
            ("noisy", Predictor::uniform_noise(0.5, 11)),
        ] {
            for inc in [true, false] {
                let ctx = format!("{case} spec={spec} pred={pname} inc={inc}");
                let (out, trace) = record_sim(inst, spec, &pred, &UnitTime, "unit", 9, cfg(inc))
                    .map_err(|e| format!("{ctx}: record failed: {e:#}"))?;
                let replayed = replay_sim(&trace, &UnitTime)
                    .map_err(|e| format!("{ctx}: replay failed: {e}"))?;
                assert_identical(&out, &replayed, &ctx);
                let reparsed = Trace::from_text(&trace.to_text())
                    .map_err(|e| format!("{ctx}: reparse failed: {e:#}"))?;
                assert_eq!(trace, reparsed, "{ctx}: text round-trip must be exact");
                let replayed2 = replay_sim(&reparsed, &UnitTime)
                    .map_err(|e| format!("{ctx}: reparsed replay failed: {e}"))?;
                assert_identical(&out, &replayed2, &ctx);
            }
        }
    }
    Ok(())
}

/// 60 fully random small instances via the in-repo property framework.
#[test]
fn record_replay_roundtrips_on_random_instances() {
    forall_cases(0x7E1A7, 60, usize_in(0, u32::MAX as usize), |&seed| {
        let mut rng = Rng::new(seed as u64);
        let m = rng.i64_range(8, 50) as u64;
        let n = rng.usize_range(1, 30);
        let reqs: Vec<Request> = (0..n)
            .map(|i| {
                let s = rng.i64_range(1, 5) as u64;
                let o = rng.i64_range(1, (m - s).min(14) as i64) as u64;
                let a = rng.i64_range(0, 8) as f64;
                Request::new(i, a, s, o)
            })
            .collect();
        check_roundtrip(&Instance::new(m, reqs), &format!("seed={seed:#x}"))
    });
}

/// Instances from the paper's §5.1 synthetic arrival models.
#[test]
fn record_replay_roundtrips_on_paper_arrival_models() {
    let mut rng = Rng::new(0x7A0E);
    for trial in 0..10 {
        let inst = synthetic::arrival_model_1(&mut rng);
        check_roundtrip(&inst, &format!("model1 trial={trial}")).unwrap();
    }
    for trial in 0..10 {
        let inst = synthetic::arrival_model_2(&mut rng);
        check_roundtrip(&inst, &format!("model2 trial={trial}")).unwrap();
    }
}

/// The Thm-4.1 adversarial construction: long-request head-of-line
/// pressure with a burst release.
#[test]
fn record_replay_roundtrips_on_adversarial_instances() {
    for m in [16u64, 64] {
        let inst = synthetic::adversarial_thm41(m, 0);
        check_roundtrip(&inst, &format!("thm41 m={m}")).unwrap();
    }
}

/// A 1-worker fleet trace is the single-worker trace plus `route`
/// events, and its replay reduces to the single-worker outcome — the
/// trace-level form of `tests/cluster_reduction.rs`.
#[test]
fn one_worker_fleet_trace_reduces_to_single_worker_trace() {
    let mut rng = Rng::new(0x7A11);
    for trial in 0..4 {
        let inst = synthetic::arrival_model_2(&mut rng);
        let (base, strace) = record_sim(
            &inst,
            "mcsf",
            &Predictor::exact(),
            &UnitTime,
            "unit",
            9,
            cfg(true),
        )
        .unwrap();
        for router in ["rr", "po2"] {
            let ctx = format!("trial={trial} router={router}");
            let (fout, ftrace) = record_fleet(
                &inst,
                "mcsf",
                router,
                1,
                None,
                &Predictor::exact(),
                &UnitTime,
                "unit",
                9,
                cfg(true),
            )
            .unwrap();
            assert_identical(&base, &fout.per_worker[0], &ctx);
            let stripped: Vec<TraceEvent> = ftrace
                .events
                .iter()
                .filter(|e| !matches!(e, TraceEvent::Route { .. }))
                .cloned()
                .collect();
            assert_eq!(
                strace.events, stripped,
                "{ctx}: fleet trace minus route events must equal the single-worker trace"
            );
            let replayed = replay_fleet(&ftrace, &UnitTime)
                .unwrap_or_else(|e| panic!("{ctx}: fleet replay failed: {e}"));
            assert_identical(&base, &replayed.per_worker[0], &ctx);
        }
    }
}

/// Multi-worker fleet traces replay every worker bit-identically, and
/// survive the on-disk round-trip.
#[test]
fn multi_worker_fleet_records_replay_bit_identically() {
    let mut rng = Rng::new(0xFA57);
    for trial in 0..3 {
        let inst = synthetic::arrival_model_2(&mut rng);
        for router in ["po2", "rr"] {
            let ctx = format!("trial={trial} router={router}");
            let (out, trace) = record_fleet(
                &inst,
                "mcsf",
                router,
                3,
                None,
                &Predictor::exact(),
                &UnitTime,
                "unit",
                9,
                cfg(true),
            )
            .unwrap();
            let replayed = replay_fleet(&trace, &UnitTime)
                .unwrap_or_else(|e| panic!("{ctx}: replay failed: {e}"));
            assert_eq!(out.assigned(), replayed.assigned(), "{ctx}: assigned");
            for w in 0..3 {
                assert_identical(
                    &out.per_worker[w],
                    &replayed.per_worker[w],
                    &format!("{ctx} worker={w}"),
                );
            }
            let path = std::env::temp_dir().join(format!("kvsched_rt_{trial}_{router}.trace"));
            let path = path.to_str().unwrap();
            trace.save(path).unwrap();
            let loaded = Trace::load(path).unwrap();
            let _ = std::fs::remove_file(path);
            assert_eq!(trace, loaded, "{ctx}: disk round-trip");
            let replayed2 = replay_fleet(&loaded, &UnitTime)
                .unwrap_or_else(|e| panic!("{ctx}: loaded replay failed: {e}"));
            assert_eq!(out.assigned(), replayed2.assigned(), "{ctx}: loaded assigned");
        }
    }
}

/// A tampered trace must fail with a divergence pinpointing the exact
/// event that no longer matches.
#[test]
fn tampered_trace_reports_first_diverging_event() {
    let mut rng = Rng::new(0xBAD);
    let inst = synthetic::arrival_model_2(&mut rng);
    let (_, mut trace) = record_sim(
        &inst,
        "mcsf",
        &Predictor::exact(),
        &UnitTime,
        "unit",
        9,
        cfg(true),
    )
    .unwrap();
    let pos = trace
        .events
        .iter()
        .rposition(|e| matches!(e, TraceEvent::Complete { .. }))
        .expect("a finished run records completions");
    if let TraceEvent::Complete { round, .. } = &mut trace.events[pos] {
        *round += 1;
    }
    match replay_sim(&trace, &UnitTime) {
        Err(ReplayError::Divergence(d)) => {
            assert_eq!(d.index, pos, "divergence must point at the tampered event");
            assert!(format!("{d}").contains("diverges"), "diagnostic: {d}");
        }
        Err(other) => panic!("expected a divergence, got: {other}"),
        Ok(_) => panic!("tampered trace must not replay clean"),
    }
}

fn golden_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("crate lives under the workspace root")
        .join("golden")
}

/// Compare a freshly recorded trace against the committed fixture,
/// bootstrapping the fixture when it doesn't exist yet (first run / a
/// fresh checkout without goldens) and regenerating it under
/// `UPDATE_GOLDEN=1`. CI follows this test with
/// `git diff --exit-code -- golden` so a drifted committed fixture
/// fails the build even if the bootstrap path rewrote it.
fn check_golden(name: &str, fresh: &Trace) {
    let dir = golden_dir();
    let path = dir.join(name);
    if std::env::var_os("UPDATE_GOLDEN").is_some() || !path.exists() {
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(&path, fresh.to_text()).unwrap();
    }
    let committed = Trace::load(path.to_str().unwrap()).unwrap();
    assert_eq!(
        &committed, fresh,
        "golden {name} drifted — regenerate with golden/regen.sh if the change is intended"
    );
}

/// The golden corpus: one single-worker discrete run, one fleet×router
/// run, one SLO class-mix run. Each must match its committed fixture
/// byte-for-byte (via the parsed form) and replay bit-identically.
#[test]
fn golden_traces_replay_bit_identically() {
    let mut rng = Rng::new(0x601D);

    let inst = synthetic::arrival_model_2(&mut rng);
    let (out, trace) = record_sim(
        &inst,
        "mcsf",
        &Predictor::exact(),
        &UnitTime,
        "unit",
        9,
        cfg(true),
    )
    .unwrap();
    check_golden("single_mcsf.trace", &trace);
    let replayed = replay_sim(&trace, &UnitTime).unwrap();
    assert_identical(&out, &replayed, "golden single_mcsf");

    let inst = synthetic::arrival_model_2(&mut rng);
    let (fout, ftrace) = record_fleet(
        &inst,
        "mcsf",
        "po2",
        3,
        None,
        &Predictor::exact(),
        &UnitTime,
        "unit",
        9,
        cfg(true),
    )
    .unwrap();
    check_golden("fleet_po2.trace", &ftrace);
    let freplayed = replay_fleet(&ftrace, &UnitTime).unwrap();
    for w in 0..3 {
        assert_identical(
            &fout.per_worker[w],
            &freplayed.per_worker[w],
            &format!("golden fleet_po2 worker={w}"),
        );
    }

    let classes = ClassSet::parse("interactive:0.7,batch:0.3").unwrap();
    let inst = ClassMixGen::new(classes, 200).instance(40, 10.0, 200, &mut rng);
    let (sout, strace) = record_sim(
        &inst,
        "priority",
        &Predictor::exact(),
        &UnitTime,
        "unit",
        9,
        cfg(true),
    )
    .unwrap();
    check_golden("slo_priority.trace", &strace);
    let sreplayed = replay_sim(&strace, &UnitTime).unwrap();
    assert_identical(&sout, &sreplayed, "golden slo_priority");
}

/// A sustained-overload instance small enough for the test suite but
/// hot enough that queue-threshold admission actually rejects, retries,
/// and sheds — so the recorded trace carries all three flow event kinds.
fn overload_instance(seed: u64) -> Instance {
    let gen = overload::preset("sustained", 140, &UnitTime, 80).unwrap();
    gen.instance(80, 140, &mut Rng::new(seed))
}

/// Flow-controlled recordings (rejections, retries, sheds) replay to
/// bit-identical outcomes — including the flow counters — on both
/// engine paths and through the text round-trip, single-worker and
/// fleet alike.
#[test]
fn overload_flow_records_replay_bit_identically() {
    let inst = overload_instance(0x0BAD_CAFE);
    let spec = FlowSpec::new("queue-threshold:threshold=0.6");
    for inc in [true, false] {
        let ctx = format!("overload sim inc={inc}");
        let (out, trace) = record_sim_flow(
            &inst,
            "mcsf",
            &Predictor::exact(),
            &UnitTime,
            "unit",
            9,
            cfg(inc),
            Some(&spec),
        )
        .unwrap();
        let stats = out.flow.as_ref().expect("flow stats recorded");
        assert!(stats.rejected > 0, "{ctx}: the scenario must reject");
        assert!(stats.retries > 0, "{ctx}: the scenario must retry");
        assert!(
            trace
                .events
                .iter()
                .any(|e| matches!(e, TraceEvent::Retry { .. })),
            "{ctx}: retry events recorded"
        );
        let replayed = replay_sim(&trace, &UnitTime)
            .unwrap_or_else(|e| panic!("{ctx}: replay failed: {e}"));
        assert_identical(&out, &replayed, &ctx);
        assert_eq!(out.flow, replayed.flow, "{ctx}: flow counters");
        let reparsed = Trace::from_text(&trace.to_text()).unwrap();
        assert_eq!(trace, reparsed, "{ctx}: text round-trip");
        let replayed2 = replay_sim(&reparsed, &UnitTime).unwrap();
        assert_identical(&out, &replayed2, &ctx);
    }

    let (fout, ftrace) = record_fleet_flow(
        &inst,
        "mcsf",
        "po2",
        2,
        None,
        &Predictor::exact(),
        &UnitTime,
        "unit",
        9,
        cfg(true),
        Some(&spec),
    )
    .unwrap();
    let freplayed = replay_fleet(&ftrace, &UnitTime).unwrap();
    assert_eq!(fout.assigned(), freplayed.assigned(), "fleet assigned");
    assert_eq!(fout.flow, freplayed.flow, "fleet flow counters");
    for w in 0..2 {
        assert_identical(
            &fout.per_worker[w],
            &freplayed.per_worker[w],
            &format!("overload fleet worker={w}"),
        );
    }
    let reparsed = Trace::from_text(&ftrace.to_text()).unwrap();
    assert_eq!(ftrace, reparsed, "overload fleet text round-trip");
}

/// A tampered retry event — the modeled client re-arriving at the wrong
/// instant — must surface as a divergence at exactly that event.
#[test]
fn tampered_retry_event_reports_divergence() {
    let inst = overload_instance(0xBAD2);
    let spec = FlowSpec::new("queue-threshold:threshold=0.6");
    let (_, mut trace) = record_sim_flow(
        &inst,
        "mcsf",
        &Predictor::exact(),
        &UnitTime,
        "unit",
        9,
        cfg(true),
        Some(&spec),
    )
    .unwrap();
    let pos = trace
        .events
        .iter()
        .position(|e| matches!(e, TraceEvent::Retry { .. }))
        .expect("an overloaded qt run schedules retries");
    if let TraceEvent::Retry { at, .. } = &mut trace.events[pos] {
        *at += 0.25;
    }
    match replay_sim(&trace, &UnitTime) {
        Err(ReplayError::Divergence(d)) => {
            assert_eq!(d.index, pos, "divergence must point at the tampered retry");
        }
        Err(other) => panic!("expected a divergence, got: {other}"),
        Ok(_) => panic!("tampered retry must not replay clean"),
    }
}

/// Traces are engine-independent: recording the same run on the round
/// engine and the event engine yields byte-identical trace text (quiet
/// rounds record no events, so skipping them changes nothing), and a
/// trace recorded under `--engine event` replays clean through the
/// round-clock replayer — cross-engine replay in both framings.
#[test]
fn traces_are_engine_independent_and_replay_cross_engine() {
    let mut rng = Rng::new(0xE7A7);
    for trial in 0..4 {
        let inst = synthetic::arrival_model_2(&mut rng);
        for (fname, fspec) in [
            ("none", None),
            ("qt", Some(FlowSpec::new("queue-threshold:threshold=0.6"))),
        ] {
            let ctx = format!("trial={trial} flow={fname}");
            let record_on = |engine: EngineKind| {
                record_sim_flow(
                    &inst,
                    "mcsf",
                    &Predictor::exact(),
                    &UnitTime,
                    "unit",
                    9,
                    SimConfig { engine, ..cfg(true) },
                    fspec.as_ref(),
                )
                .unwrap()
            };
            let (rout, rtrace) = record_on(EngineKind::Round);
            let (eout, etrace) = record_on(EngineKind::Event);
            assert_identical(&rout, &eout, &ctx);
            assert_eq!(rout.flow, eout.flow, "{ctx}: flow counters");
            assert_eq!(
                rtrace.to_text(),
                etrace.to_text(),
                "{ctx}: trace text must not depend on the recording engine"
            );
            // The replayer runs on the round clock; feeding it the
            // event-recorded trace is a cross-engine replay.
            let replayed = replay_sim(&etrace, &UnitTime)
                .unwrap_or_else(|e| panic!("{ctx}: cross-engine replay failed: {e}"));
            assert_identical(&eout, &replayed, &ctx);
        }
    }
}

/// Chunked-prefill recordings carry the chunk in the meta, replay
/// bit-identically through the text round-trip, and stay
/// engine-independent (round vs event recordings are byte-identical).
#[test]
fn chunked_prefill_records_replay_bit_identically() {
    let mut rng = Rng::new(0xC4E4);
    for trial in 0..4 {
        let inst = synthetic::arrival_model_2(&mut rng);
        for chunk in [1u64, 3] {
            let ctx = format!("trial={trial} chunk={chunk}");
            let record_on = |engine: EngineKind| {
                record_sim(
                    &inst,
                    "mcsf",
                    &Predictor::exact(),
                    &UnitTime,
                    "unit",
                    9,
                    SimConfig {
                        engine,
                        prefill_chunk: chunk,
                        ..cfg(true)
                    },
                )
                .unwrap()
            };
            let (rout, rtrace) = record_on(EngineKind::Round);
            let (eout, etrace) = record_on(EngineKind::Event);
            assert_identical(&rout, &eout, &ctx);
            assert_eq!(
                rtrace.to_text(),
                etrace.to_text(),
                "{ctx}: chunked trace text must not depend on the engine"
            );
            assert_eq!(rtrace.meta.prefill_chunk, chunk, "{ctx}: meta chunk");
            let reparsed = Trace::from_text(&rtrace.to_text()).unwrap();
            assert_eq!(rtrace, reparsed, "{ctx}: text round-trip");
            assert_eq!(reparsed.meta.prefill_chunk, chunk, "{ctx}: reparsed chunk");
            let replayed = replay_sim(&reparsed, &UnitTime)
                .unwrap_or_else(|e| panic!("{ctx}: replay failed: {e}"));
            assert_identical(&rout, &replayed, &ctx);
        }
    }
}

/// Disaggregated recordings: the trace carries the spec string and the
/// decode tier's KV-transfer events, survives the text round-trip
/// exactly, replays to a bit-identical stitched outcome, and is
/// engine-independent — including cross-engine replay (the replayer's
/// round clock consuming an event-engine recording).
#[test]
fn disagg_records_replay_bit_identically() {
    let mut rng = Rng::new(0xD15A6);
    for trial in 0..3 {
        let inst = synthetic::arrival_model_2(&mut rng);
        let spec = DisaggSpec {
            prefill_workers: 1,
            transfer_latency: 0.25,
            transfer_per_token: 0.01,
        };
        let ctx = format!("trial={trial}");
        let record_on = |engine: EngineKind| {
            record_fleet_disagg(
                &inst,
                "mcsf",
                spec,
                3,
                None,
                &Predictor::exact(),
                &UnitTime,
                "unit",
                9,
                SimConfig { engine, ..cfg(true) },
            )
            .unwrap()
        };
        let (rout, rtrace) = record_on(EngineKind::Round);
        let (eout, etrace) = record_on(EngineKind::Event);
        assert_eq!(rtrace.meta.disagg.as_deref(), Some(spec.spec_string().as_str()));
        assert!(
            rtrace
                .events
                .iter()
                .any(|e| matches!(e, TraceEvent::Transfer { .. })),
            "{ctx}: KV-transfer events recorded"
        );
        assert_eq!(
            rtrace.to_text(),
            etrace.to_text(),
            "{ctx}: disagg trace text must not depend on the engine"
        );
        let reparsed = Trace::from_text(&rtrace.to_text()).unwrap();
        assert_eq!(rtrace, reparsed, "{ctx}: text round-trip");
        for (name, trace, out) in [("round", &reparsed, &rout), ("event", &etrace, &eout)] {
            let replayed = replay_fleet(trace, &UnitTime)
                .unwrap_or_else(|e| panic!("{ctx}: {name} replay failed: {e}"));
            assert_eq!(out.completed(), replayed.completed(), "{ctx} {name}");
            for w in 0..3 {
                assert_identical(
                    &out.per_worker[w],
                    &replayed.per_worker[w],
                    &format!("{ctx} {name} worker={w}"),
                );
            }
        }
    }
}

/// A tampered KV-transfer event must surface as a divergence at exactly
/// that event.
#[test]
fn tampered_transfer_event_reports_divergence() {
    let mut rng = Rng::new(0xD15AB);
    let inst = synthetic::arrival_model_2(&mut rng);
    let (_, mut trace) = record_fleet_disagg(
        &inst,
        "mcsf",
        DisaggSpec {
            transfer_latency: 0.5,
            ..DisaggSpec::default()
        },
        2,
        None,
        &Predictor::exact(),
        &UnitTime,
        "unit",
        9,
        cfg(true),
    )
    .unwrap();
    let pos = trace
        .events
        .iter()
        .position(|e| matches!(e, TraceEvent::Transfer { .. }))
        .expect("a multi-token run hands prefills to the decode tier");
    if let TraceEvent::Transfer { t, .. } = &mut trace.events[pos] {
        *t += 0.125;
    }
    match replay_fleet(&trace, &UnitTime) {
        Err(ReplayError::Divergence(d)) => {
            assert_eq!(d.index, pos, "divergence must point at the tampered transfer");
        }
        Err(other) => panic!("expected a divergence, got: {other}"),
        Ok(_) => panic!("tampered transfer must not replay clean"),
    }
}

/// The committed prefill/decode fixture: a chunked-prefill disaggregated
/// run — phase split and KV-transfer events together — must keep
/// matching its golden trace byte-for-byte and replaying bit-identically
/// on both engines (the event-engine replay consumes the same fixture).
#[test]
fn golden_phase_disagg_trace_replays_bit_identically() {
    let mut rng = Rng::new(0x601D_9);
    let inst = synthetic::arrival_model_2(&mut rng);
    let spec = DisaggSpec {
        prefill_workers: 1,
        transfer_latency: 0.5,
        transfer_per_token: 0.01,
    };
    let (out, trace) = record_fleet_disagg(
        &inst,
        "mcsf",
        spec,
        3,
        None,
        &Predictor::exact(),
        &UnitTime,
        "unit",
        9,
        SimConfig {
            prefill_chunk: 2,
            ..cfg(true)
        },
    )
    .unwrap();
    assert!(
        trace
            .events
            .iter()
            .any(|e| matches!(e, TraceEvent::Transfer { .. })),
        "fixture must carry KV-transfer events"
    );
    check_golden("phase_disagg.trace", &trace);
    let replayed = replay_fleet(&trace, &UnitTime).unwrap();
    for w in 0..3 {
        assert_identical(
            &out.per_worker[w],
            &replayed.per_worker[w],
            &format!("golden phase_disagg worker={w}"),
        );
    }
}

/// The committed overload fixture: a sustained-overload queue-threshold
/// run with rejections and retries must keep matching its golden trace
/// and replaying bit-identically.
#[test]
fn golden_overload_trace_replays_bit_identically() {
    let inst = overload_instance(0x601D_F10);
    let spec = FlowSpec::new("queue-threshold:threshold=0.6");
    let (out, trace) = record_sim_flow(
        &inst,
        "mcsf",
        &Predictor::exact(),
        &UnitTime,
        "unit",
        9,
        cfg(true),
        Some(&spec),
    )
    .unwrap();
    check_golden("overload_qt.trace", &trace);
    let replayed = replay_sim(&trace, &UnitTime).unwrap();
    assert_identical(&out, &replayed, "golden overload_qt");
    assert_eq!(out.flow, replayed.flow, "golden overload_qt flow counters");
}
