//! End-to-end runtime tests: the Rust PJRT path must reproduce the JAX
//! reference outputs recorded in `artifacts/goldens.json` at AOT time,
//! and the live coordinator must serve batched requests through the full
//! scheduler → prefill → decode pipeline.
//!
//! Requires `make artifacts`; tests self-skip when artifacts are absent
//! (CI runs them via `make test`). The whole file needs the real PJRT
//! engine, i.e. the `xla` feature; the offline build runs the same
//! coordinator pipeline against the stub engine in
//! `coordinator_offline.rs` instead.
#![cfg(feature = "xla")]

use kvsched::coordinator::{Coordinator, CoordinatorConfig, ServeRequest};
use kvsched::runtime::kv_cache::RowCache;
use kvsched::runtime::{engine::argmax, Engine};
use kvsched::sched::McSf;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

macro_rules! require_artifacts {
    () => {
        match artifacts_dir() {
            Some(d) => d,
            None => {
                eprintln!("skipping: artifacts not built (`make artifacts`)");
                return;
            }
        }
    };
}

#[test]
fn golden_prefill_logits_match_jax() {
    let dir = require_artifacts!();
    let engine = Engine::load(&dir).unwrap();
    let goldens = engine.manifest().goldens().unwrap();

    let prompt: Vec<u8> = goldens
        .req_arr("prompt")
        .unwrap()
        .iter()
        .map(|v| v.as_usize().unwrap() as u8)
        .collect();
    let expect_head: Vec<f64> = goldens
        .req_arr("prefill_logits_head")
        .unwrap()
        .iter()
        .map(|v| v.as_f64().unwrap())
        .collect();

    let mut row = RowCache::new(engine.dims());
    let out = engine.prefill(&[&prompt], &mut [&mut row]).unwrap();
    assert_eq!(row.len, prompt.len());
    for (i, (&got, &want)) in out.logits[0].iter().zip(&expect_head).enumerate() {
        assert!(
            (got as f64 - want).abs() < 1e-3,
            "logit {i}: rust {got} vs jax {want}"
        );
    }
}

#[test]
fn golden_greedy_decode_matches_jax() {
    let dir = require_artifacts!();
    let engine = Engine::load(&dir).unwrap();
    let goldens = engine.manifest().goldens().unwrap();

    let prompt: Vec<u8> = goldens
        .req_arr("prompt")
        .unwrap()
        .iter()
        .map(|v| v.as_usize().unwrap() as u8)
        .collect();
    let expect: Vec<i32> = goldens
        .req_arr("greedy_tokens")
        .unwrap()
        .iter()
        .map(|v| v.as_i64().unwrap() as i32)
        .collect();

    let mut row = RowCache::new(engine.dims());
    let out = engine.prefill(&[&prompt], &mut [&mut row]).unwrap();
    let mut tok = argmax(&out.logits[0]);
    let mut got = Vec::new();
    for _ in 0..expect.len() {
        got.push(tok);
        let logits = engine.decode(&[tok], &mut [&mut row]).unwrap();
        tok = argmax(&logits[0]);
    }
    assert_eq!(got, expect, "greedy trajectory diverged from JAX");
}

#[test]
fn decode_matches_across_batch_buckets() {
    // The same request must produce identical tokens whether it runs in
    // a batch of 1 or padded into a larger bucket (row independence +
    // padding correctness through the whole PJRT path).
    let dir = require_artifacts!();
    let engine = Engine::load(&dir).unwrap();

    let prompt_a: &[u8] = b"alpha";
    let prompt_b: &[u8] = b"beta request";

    // Solo run of A.
    let mut row_a = RowCache::new(engine.dims());
    let out = engine.prefill(&[prompt_a], &mut [&mut row_a]).unwrap();
    let mut tok_a = argmax(&out.logits[0]);
    let mut solo = vec![tok_a];
    for _ in 0..4 {
        let lg = engine.decode(&[tok_a], &mut [&mut row_a]).unwrap();
        tok_a = argmax(&lg[0]);
        solo.push(tok_a);
    }

    // Batched run of A + B.
    let mut ra = RowCache::new(engine.dims());
    let mut rb = RowCache::new(engine.dims());
    let out = engine
        .prefill(&[prompt_a, prompt_b], &mut [&mut ra, &mut rb])
        .unwrap();
    let mut ta = argmax(&out.logits[0]);
    let mut tb = argmax(&out.logits[1]);
    let mut batched = vec![ta];
    for _ in 0..4 {
        let lg = engine.decode(&[ta, tb], &mut [&mut ra, &mut rb]).unwrap();
        ta = argmax(&lg[0]);
        tb = argmax(&lg[1]);
        batched.push(ta);
    }
    assert_eq!(solo, batched, "batching changed request A's output");
}

#[test]
fn coordinator_serves_batched_requests() {
    let dir = require_artifacts!();
    let engine = Engine::load(&dir).unwrap();
    let coord = Coordinator::start(
        engine,
        Box::new(McSf::default()),
        CoordinatorConfig::default(),
    );

    let mut rxs = Vec::new();
    for i in 0..6u64 {
        let rx = coord.submit(ServeRequest {
            prompt: format!("request number {i}").into_bytes(),
            max_new_tokens: 4 + i,
            predicted_new_tokens: 4 + i,
            class: 0,
        });
        rxs.push((i, rx));
    }
    for (i, rx) in rxs {
        let reply = rx
            .recv_timeout(std::time::Duration::from_secs(120))
            .expect("coordinator reply");
        assert_eq!(reply.tokens.len() as u64, 4 + i);
        assert!(reply.latency >= 0.0 && reply.queue_wait >= 0.0);
        assert!(reply.latency >= reply.queue_wait);
    }
    let stats = coord.shutdown();
    assert!(stats.finished);
    assert_eq!(stats.per_request.len(), 6);
    assert!(stats.rounds > 0);
}

#[test]
fn coordinator_respects_memory_budget() {
    let dir = require_artifacts!();
    let engine = Engine::load(&dir).unwrap();
    let capacity = engine.dims().c as u64;
    // Budget for ~2 concurrent rows.
    let coord = Coordinator::start(
        engine,
        Box::new(McSf::default()),
        CoordinatorConfig {
            kv_budget: 2 * capacity,
            seed: 0,
            ..CoordinatorConfig::default()
        },
    );
    let mut rxs = Vec::new();
    for _ in 0..5 {
        rxs.push(coord.submit(ServeRequest {
            prompt: b"tight memory".to_vec(),
            max_new_tokens: 6,
            predicted_new_tokens: 6,
            class: 0,
        }));
    }
    for rx in rxs {
        rx.recv_timeout(std::time::Duration::from_secs(120))
            .expect("reply under tight budget");
    }
    let stats = coord.shutdown();
    // The scheduler's accounting must keep usage under the budget.
    assert!(stats.max_mem() <= 2 * capacity);
}
