//! Offline end-to-end coordinator tests: the full scheduler → prefill →
//! decode serving pipeline against the deterministic stub engine, so the
//! incremental scheduling interface (`on_arrival` / `admit_incremental`
//! / `on_complete`) is exercised through the live path without PJRT
//! artifacts. The real-engine twin of this file is `runtime_e2e.rs`.
#![cfg(not(feature = "xla"))]

use kvsched::cluster::router_by_name;
use kvsched::coordinator::{Coordinator, CoordinatorConfig, FleetCoordinator, ServeRequest};
use kvsched::runtime::Engine;
use kvsched::sched::by_name;

#[test]
fn coordinator_serves_batched_requests_incrementally() {
    let coord = Coordinator::start(
        Engine::mock(),
        by_name("mcsf").unwrap(),
        CoordinatorConfig::default(),
    );

    let mut rxs = Vec::new();
    for i in 0..6u64 {
        let rx = coord.submit(ServeRequest {
            prompt: format!("request number {i}").into_bytes(),
            max_new_tokens: 4 + i,
            predicted_new_tokens: 4 + i,
            class: 0,
        });
        rxs.push((i, rx));
    }
    for (i, rx) in rxs {
        let reply = rx
            .recv_timeout(std::time::Duration::from_secs(60))
            .expect("coordinator reply");
        assert_eq!(reply.tokens.len() as u64, 4 + i);
        assert!(reply.latency >= 0.0 && reply.queue_wait >= 0.0);
        assert!(reply.latency >= reply.queue_wait);
    }
    let stats = coord.shutdown();
    assert!(stats.finished);
    assert_eq!(stats.per_request.len(), 6);
    assert!(stats.rounds > 0);
}

#[test]
fn coordinator_respects_memory_budget_incrementally() {
    let engine = Engine::mock();
    let capacity = engine.dims().c as u64;
    // Budget for ~2 concurrent rows.
    let coord = Coordinator::start(
        engine,
        by_name("mcsf").unwrap(),
        CoordinatorConfig {
            kv_budget: 2 * capacity,
            seed: 0,
            ..CoordinatorConfig::default()
        },
    );
    let mut rxs = Vec::new();
    for _ in 0..5 {
        rxs.push(coord.submit(ServeRequest {
            prompt: b"tight memory".to_vec(),
            max_new_tokens: 6,
            predicted_new_tokens: 6,
            class: 0,
        }));
    }
    for rx in rxs {
        rx.recv_timeout(std::time::Duration::from_secs(60))
            .expect("reply under tight budget");
    }
    let stats = coord.shutdown();
    // The scheduler's accounting must keep usage under the budget.
    assert!(stats.max_mem() <= 2 * capacity);
}

#[test]
fn fcfs_and_mc_benchmark_serve_through_both_paths() {
    // MC-Benchmark takes the incremental path, FCFS the snapshot path;
    // both must drain the same workload to completion.
    for spec in ["mc-benchmark", "fcfs:threshold=0.9"] {
        let coord = Coordinator::start(
            Engine::mock(),
            by_name(spec).unwrap(),
            CoordinatorConfig::default(),
        );
        let mut rxs = Vec::new();
        for i in 0..4u64 {
            rxs.push(coord.submit(ServeRequest {
                prompt: format!("{spec} {i}").into_bytes(),
                max_new_tokens: 3,
                predicted_new_tokens: 3,
                class: 0,
            }));
        }
        for rx in rxs {
            let reply = rx
                .recv_timeout(std::time::Duration::from_secs(60))
                .expect("reply");
            assert_eq!(reply.tokens.len(), 3, "{spec}");
        }
        let stats = coord.shutdown();
        assert_eq!(stats.per_request.len(), 4, "{spec}");
    }
}

#[test]
fn fleet_coordinator_serves_across_replicas() {
    // Every router must drain a 2-replica fleet end to end; the routed
    // requests partition across workers and each reply arrives once.
    for router in ["rr", "jsq", "least-kv", "po2"] {
        let engines = vec![Engine::mock(), Engine::mock()];
        let scheds = vec![by_name("mcsf").unwrap(), by_name("mcsf").unwrap()];
        let fleet = FleetCoordinator::start(
            engines,
            scheds,
            router_by_name(router).unwrap(),
            CoordinatorConfig::default(),
        );
        assert_eq!(fleet.workers(), 2);
        let mut rxs = Vec::new();
        for i in 0..8u64 {
            let (worker, rx) = fleet.submit(ServeRequest {
                prompt: format!("fleet {router} {i}").into_bytes(),
                max_new_tokens: 3,
                predicted_new_tokens: 3,
                class: 0,
            });
            assert!(worker < 2, "{router}");
            rxs.push(rx);
        }
        for rx in rxs {
            let reply = rx
                .recv_timeout(std::time::Duration::from_secs(60))
                .expect("fleet reply");
            assert_eq!(reply.tokens.len(), 3, "{router}");
        }
        let out = fleet.shutdown();
        assert_eq!(out.workers(), 2, "{router}");
        assert_eq!(out.completed(), 8, "{router}");
        assert_eq!(out.assigned().iter().sum::<usize>(), 8, "{router}");
        assert!(out.finished(), "{router}");
        // Round-robin must split 8 submissions exactly 4 / 4.
        if router == "rr" {
            assert_eq!(out.assigned(), vec![4, 4]);
        }
    }
}
