//! Cross-module integration tests: workload generation → scheduling →
//! simulation → metrics → hindsight optimum, exactly the pipelines the
//! paper's experiments run.

use kvsched::core::{Instance, Request};
use kvsched::opt::{self, HindsightConfig};
use kvsched::perf::{Llama70bA100x2, PerfModel, UnitTime};
use kvsched::predictor::Predictor;
use kvsched::sched::{by_name, paper_benchmark_suite, McSf};
use kvsched::sim::{continuous, discrete, SimConfig};
use kvsched::util::rng::Rng;
use kvsched::workload::{lmsys::LmsysGen, synthetic};

#[test]
fn synthetic_model1_mcsf_vs_hindsight_small() {
    // The §5.1 pipeline at unit-test scale: MC-SF's ratio to the proven
    // optimum must be ≥ 1 and typically very close to 1.
    let mut rng = Rng::new(2024);
    let mut ratios = Vec::new();
    for _ in 0..4 {
        // Down-scaled Arrival Model 1 (keeps the IP tiny).
        let m = rng.i64_range(12, 18) as u64;
        let n = rng.usize_range(6, 9);
        let reqs: Vec<Request> = (0..n)
            .map(|i| {
                let s = rng.i64_range(1, 3) as u64;
                let o = rng.i64_range(1, (m - s).min(8) as i64) as u64;
                Request::new(i, 0.0, s, o)
            })
            .collect();
        let inst = Instance::new(m, reqs);
        let sol = opt::hindsight_optimal(&inst, &HindsightConfig::default()).unwrap();
        assert!(sol.proven_optimal);
        let out = discrete::simulate(&inst, &mut McSf::default(), &Predictor::exact(), 1);
        let ratio = out.total_latency() / sol.total_latency;
        assert!(ratio >= 1.0 - 1e-9, "ratio {ratio} below 1");
        ratios.push(ratio);
    }
    let avg = ratios.iter().sum::<f64>() / ratios.len() as f64;
    assert!(avg < 1.35, "avg ratio {avg} too far from optimal");
}

#[test]
fn full_benchmark_suite_runs_on_lmsys_workload() {
    // §5.2 pipeline (scaled down): every algorithm in the paper's suite
    // over the same LMSYS-like trace with the Llama2-70B perf model.
    let gen = LmsysGen::default();
    let mut rng = Rng::new(7);
    let inst = gen.instance(120, 50.0, continuous::PAPER_M, &mut rng);
    let perf = Llama70bA100x2::default();
    let mut latencies = Vec::new();
    for mut sched in paper_benchmark_suite() {
        let out = continuous::try_simulate(
            &inst,
            sched.as_mut(),
            &Predictor::exact(),
            &perf,
            1,
            SimConfig {
                max_rounds: 200_000,
                record_series: false,
                ..SimConfig::default()
            },
        )
        .unwrap();
        assert!(out.finished, "{} diverged", out.algo);
        assert_eq!(out.per_request.len(), inst.n());
        assert!(out.max_mem() <= continuous::PAPER_M + 200); // small α can exceed transiently pre-clearing
        latencies.push((out.algo.clone(), out.avg_latency()));
    }
    // MC-SF should be the best or near-best policy.
    let mcsf = latencies[0].1;
    let best = latencies
        .iter()
        .map(|&(_, l)| l)
        .fold(f64::INFINITY, f64::min);
    assert!(
        mcsf <= best * 1.10 + 1e-9,
        "MC-SF {mcsf} not near best {best}: {latencies:?}"
    );
}

#[test]
fn trace_roundtrip_preserves_simulation() {
    let gen = LmsysGen::default();
    let mut rng = Rng::new(9);
    let inst = gen.instance(40, 10.0, continuous::PAPER_M, &mut rng);
    let path = std::env::temp_dir().join("kvsched_integration_trace.json");
    inst.save(path.to_str().unwrap()).unwrap();
    let back = Instance::load(path.to_str().unwrap()).unwrap();
    let _ = std::fs::remove_file(&path);

    let perf = Llama70bA100x2::default();
    let a = continuous::simulate(&inst, &mut McSf::default(), &Predictor::exact(), &perf, 3);
    let b = continuous::simulate(&back, &mut McSf::default(), &Predictor::exact(), &perf, 3);
    assert_eq!(a.total_latency(), b.total_latency());
}

#[test]
fn prediction_noise_with_protection_margin_stays_safe() {
    // §5.2.2: with ε-noisy predictions and the α=0.1 margin, MC-SF may
    // overflow occasionally but must recover and finish.
    let gen = LmsysGen::default();
    let mut rng = Rng::new(11);
    let inst = gen.instance(80, 50.0, continuous::PAPER_M, &mut rng);
    let perf = Llama70bA100x2::default();
    for eps in [0.2, 0.5, 0.8] {
        let pred = Predictor::uniform_noise(eps, 42);
        let mut sched = McSf::with_protection(0.1);
        let out = continuous::try_simulate(
            &inst,
            &mut sched,
            &pred,
            &perf,
            1,
            SimConfig::default(),
        )
        .unwrap();
        assert!(out.finished, "ε={eps} diverged");
        assert_eq!(out.per_request.len(), inst.n());
    }
}

#[test]
fn scheduler_factory_round_trips_through_simulation() {
    // M is generous enough that even the no-lookahead threshold policies
    // avoid the deterministic clearing livelock (which uniform instances
    // trigger by design — see engine::tests::alpha_protection_greedy_
    // can_loop_forever for that behaviour).
    let inst = Instance::new(
        60,
        (0..8).map(|i| Request::new(i, 0.0, 2, 4)).collect(),
    );
    for spec in ["mcsf", "mcsf:alpha=0.1", "mc-benchmark", "protect:alpha=0.3", "fcfs:threshold=0.8"] {
        let mut sched = by_name(spec).unwrap();
        let out = discrete::simulate_cfg(
            &inst,
            sched.as_mut(),
            &Predictor::exact(),
            1,
            SimConfig::default(),
        );
        assert!(out.finished, "{spec} failed");
        assert_eq!(out.per_request.len(), 8, "{spec}");
    }
}

#[test]
fn thm41_adversarial_instance_hurts_online_policies() {
    // The Ω(√n) construction: MC-SF (work-conserving, starts the long
    // request immediately) pays ~M/4 short requests × √M/2 wait, while
    // OPT(≤ 3.5M) stays linear. Check the *ratio grows* with M.
    let mut ratios = Vec::new();
    for m in [64u64, 256] {
        let inst = synthetic::adversarial_thm41(m, 0);
        let out = discrete::simulate(&inst, &mut McSf::default(), &Predictor::exact(), 1);
        assert!(out.finished);
        let opt_ub = 3.5 * m as f64; // paper Eq (13)
        ratios.push(out.total_latency() / opt_ub);
    }
    assert!(
        ratios[1] > ratios[0] * 1.5,
        "adversarial ratio should grow ~√M: {ratios:?}"
    );
}

#[test]
fn discrete_and_continuous_agree_under_unit_time() {
    // The continuous engine with UnitTime must reproduce the discrete
    // semantics exactly.
    let mut rng = Rng::new(13);
    let inst = synthetic::arrival_model_2(&mut rng);
    let d = discrete::simulate(&inst, &mut McSf::default(), &Predictor::exact(), 5);
    let c = continuous::simulate(
        &inst,
        &mut McSf::default(),
        &Predictor::exact(),
        &UnitTime,
        5,
    );
    assert_eq!(d.total_latency(), c.total_latency());
    assert_eq!(d.rounds, c.rounds);
}

#[test]
fn perf_model_monotonicity_in_load() {
    let perf = Llama70bA100x2::default();
    let gen = LmsysGen::default();
    let mut rng = Rng::new(15);
    // Same 60 requests, arriving fast vs slow: average latency must be
    // (weakly) worse under the faster arrival rate.
    let lens: Vec<(u64, u64)> = (0..60).map(|_| gen.sample_lengths(&mut rng)).collect();
    let build = |lambda: f64, rng: &mut Rng| {
        let times = kvsched::workload::poisson_arrival_times(60, lambda, rng);
        Instance::new(
            continuous::PAPER_M,
            times
                .iter()
                .zip(&lens)
                .enumerate()
                .map(|(i, (&t, &(s, o)))| Request::new(i, t, s, o))
                .collect(),
        )
    };
    let mut r1 = Rng::new(99);
    let mut r2 = Rng::new(99);
    let fast = build(80.0, &mut r1);
    let slow = build(2.0, &mut r2);
    let out_fast =
        continuous::simulate(&fast, &mut McSf::default(), &Predictor::exact(), &perf, 1);
    let out_slow =
        continuous::simulate(&slow, &mut McSf::default(), &Predictor::exact(), &perf, 1);
    assert!(out_fast.avg_latency() >= out_slow.avg_latency() * 0.95);
}
