//! Reduction differential test for the fleet engine: a [`Fleet`] with
//! **one** worker — behind *any* router — must produce a `SimOutcome`
//! **bit-identical** to the single-worker engine (same admit order, same
//! per-request records, same memory/overflow/eviction counters and
//! series, same round count) across the same instance corpus as
//! `tests/incremental_diff.rs`: random small instances, the §5.1
//! arrival models, and the Thm-4.1 adversarial family, with exact and
//! noisy predictions.
//!
//! With N > 1 workers the fleet must still be conservative: every
//! request is routed exactly once, completes exactly once, and the
//! per-worker assigned counts partition the instance.

use kvsched::cluster::Fleet;
use kvsched::core::{FleetSpec, Instance, Request};
use kvsched::metrics::SimOutcome;
use kvsched::perf::UnitTime;
use kvsched::predictor::Predictor;
use kvsched::sched::by_name;
use kvsched::sim::engine::run;
use kvsched::sim::SimConfig;
use kvsched::util::prop::{forall_cases, usize_in};
use kvsched::util::rng::Rng;
use kvsched::workload::synthetic;

const ROUTERS: [&str; 5] = ["rr", "jsq", "least-kv", "po2", "slo-aware"];

/// Incremental implementations plus snapshot-only baselines — same mix
/// as the incremental_diff corpus, trimmed for the extra router axis.
const SPECS: [&str; 4] = [
    "mcsf",
    "mc-benchmark",
    "protect:alpha=0.1,beta=0.5",
    "fcfs:threshold=0.9",
];

fn cfg() -> SimConfig {
    SimConfig {
        // Bounded caps so clearing livelocks terminate quickly; both
        // engines share the caps, so truncated runs must match too.
        max_rounds: 10_000,
        stall_rounds: 1_500,
        record_series: true,
        incremental: true,
        ..SimConfig::default()
    }
}

fn assert_identical(a: &SimOutcome, b: &SimOutcome, ctx: &str) {
    assert_eq!(a.algo, b.algo, "{ctx}: algo");
    assert_eq!(a.assigned, b.assigned, "{ctx}: assigned");
    assert_eq!(a.finished, b.finished, "{ctx}: finished");
    assert_eq!(a.rounds, b.rounds, "{ctx}: rounds");
    assert_eq!(a.peak_mem, b.peak_mem, "{ctx}: peak_mem");
    assert_eq!(a.overflow_events, b.overflow_events, "{ctx}: overflows");
    assert_eq!(a.evicted_requests, b.evicted_requests, "{ctx}: evictions");
    assert_eq!(a.per_request, b.per_request, "{ctx}: per-request records");
    assert_eq!(a.mem_series, b.mem_series, "{ctx}: memory series");
    assert_eq!(a.tokens_series, b.tokens_series, "{ctx}: token series");
    assert_eq!(
        a.total_latency().to_bits(),
        b.total_latency().to_bits(),
        "{ctx}: total latency bits"
    );
}

fn check_reduction(inst: &Instance, case: &str) -> Result<(), String> {
    for spec in SPECS {
        for (pname, pred) in [
            ("exact", Predictor::exact()),
            ("noisy", Predictor::uniform_noise(0.5, 11)),
        ] {
            let mut single = by_name(spec).unwrap();
            let base = run(inst, single.as_mut(), &pred, &UnitTime, 9, cfg())
                .map_err(|e| format!("{case} spec={spec} pred={pname}: single failed: {e}"))?;
            for router in ROUTERS {
                let ctx = format!("{case} spec={spec} pred={pname} router={router}");
                let mut fleet = Fleet::new(FleetSpec::single(), spec, router).unwrap();
                let out = fleet
                    .try_simulate(inst, &pred, &UnitTime, 9, cfg())
                    .map_err(|e| format!("{ctx}: fleet failed: {e}"))?;
                assert_eq!(out.workers(), 1, "{ctx}");
                assert_identical(&base, &out.per_worker[0], &ctx);
            }
        }
    }
    Ok(())
}

/// 60 fully random small instances via the in-repo property framework.
#[test]
fn one_worker_fleet_equals_engine_on_random_instances() {
    forall_cases(0xF1EE7, 60, usize_in(0, u32::MAX as usize), |&seed| {
        let mut rng = Rng::new(seed as u64);
        let m = rng.i64_range(8, 50) as u64;
        let n = rng.usize_range(1, 30);
        let reqs: Vec<Request> = (0..n)
            .map(|i| {
                let s = rng.i64_range(1, 5) as u64;
                let o = rng.i64_range(1, (m - s).min(14) as i64) as u64;
                let a = rng.i64_range(0, 8) as f64;
                Request::new(i, a, s, o)
            })
            .collect();
        check_reduction(&Instance::new(m, reqs), &format!("seed={seed:#x}"))
    });
}

/// Instances from the paper's §5.1 synthetic arrival models.
#[test]
fn one_worker_fleet_equals_engine_on_paper_arrival_models() {
    let mut rng = Rng::new(0xC1A2);
    for trial in 0..15 {
        let inst = synthetic::arrival_model_1(&mut rng);
        check_reduction(&inst, &format!("model1 trial={trial}")).unwrap();
    }
    for trial in 0..15 {
        let inst = synthetic::arrival_model_2(&mut rng);
        check_reduction(&inst, &format!("model2 trial={trial}")).unwrap();
    }
}

/// The Thm-4.1 adversarial construction: long-request head-of-line
/// pressure with a burst release.
#[test]
fn one_worker_fleet_equals_engine_on_adversarial_instances() {
    for m in [16u64, 64] {
        let inst = synthetic::adversarial_thm41(m, 0);
        check_reduction(&inst, &format!("thm41 m={m}")).unwrap();
    }
}

/// N > 1: the fleet partitions the instance — every request is assigned
/// to exactly one worker and completes exactly once, under every router.
#[test]
fn multi_worker_fleet_partitions_requests() {
    let mut rng = Rng::new(0xBEEF);
    for trial in 0..6 {
        let inst = synthetic::arrival_model_2(&mut rng);
        for workers in [2usize, 3, 8] {
            for router in ROUTERS {
                let ctx = format!("trial={trial} workers={workers} router={router}");
                let mut fleet =
                    Fleet::new(FleetSpec::replicas(workers), "mcsf", router).unwrap();
                let out = fleet
                    .try_simulate(&inst, &Predictor::exact(), &UnitTime, 5, cfg())
                    .unwrap();
                assert!(out.finished(), "{ctx}");
                assert_eq!(out.completed(), inst.n(), "{ctx}");
                assert_eq!(
                    out.assigned().iter().sum::<usize>(),
                    inst.n(),
                    "{ctx}: assigned must partition"
                );
                let mut seen = vec![false; inst.n()];
                for w in &out.per_worker {
                    assert!(w.per_request.len() <= w.assigned, "{ctx}");
                    for r in &w.per_request {
                        assert!(!seen[r.id], "{ctx}: request {} completed twice", r.id);
                        seen[r.id] = true;
                    }
                    // Per-worker KV safety: MC-SF with exact predictions
                    // never exceeds its replica budget.
                    assert!(w.max_mem() <= inst.m, "{ctx}: worker over budget");
                }
                assert!(seen.iter().all(|&s| s), "{ctx}: some request never completed");
            }
        }
    }
}

/// Fleet runs are deterministic functions of the seed, including the
/// randomized router.
#[test]
fn fleet_runs_are_reproducible() {
    let mut rng = Rng::new(0x5EED);
    let inst = synthetic::arrival_model_2(&mut rng);
    for router in ROUTERS {
        let run_once = || {
            let mut fleet = Fleet::new(FleetSpec::replicas(4), "mcsf", router).unwrap();
            fleet
                .try_simulate(&inst, &Predictor::exact(), &UnitTime, 17, cfg())
                .unwrap()
        };
        let a = run_once();
        let b = run_once();
        assert_eq!(a.assigned(), b.assigned(), "{router}");
        assert_eq!(
            a.total_latency().to_bits(),
            b.total_latency().to_bits(),
            "{router}"
        );
        assert_eq!(a.total_rounds(), b.total_rounds(), "{router}");
    }
}