//! Property-based invariants over the scheduling stack, using the
//! in-repo `util::prop` mini-framework (no proptest offline).

use kvsched::core::{FeasItem, Instance, Request};
use kvsched::opt::{self, HindsightConfig, MilpConfig};
use kvsched::predictor::Predictor;
use kvsched::sched::feasibility::{feasible_bruteforce, FeasChecker};
use kvsched::sched::{AlphaProtection, McBenchmark, McSf, Scheduler};
use kvsched::sim::{discrete, SimConfig};
use kvsched::util::prop::{forall_cases, usize_in, Gen};
use kvsched::util::rng::Rng;

/// Generator: a random small instance (all integral arrivals).
fn gen_instance(max_n: usize, max_m: u64) -> Gen<Instance> {
    Gen {
        gen: Box::new(move |r: &mut Rng| {
            let m = r.i64_range(8, max_m as i64) as u64;
            let n = r.usize_range(1, max_n);
            let reqs = (0..n)
                .map(|i| {
                    let s = r.i64_range(1, 4) as u64;
                    let o = r.i64_range(1, (m - s).min(12) as i64) as u64;
                    let a = r.i64_range(0, 6) as f64;
                    Request::new(i, a, s, o)
                })
                .collect();
            Instance::new(m, reqs)
        }),
        shrink: Box::new(move |inst: &Instance| {
            // Shrink by dropping requests.
            let mut out = Vec::new();
            if inst.n() > 1 {
                out.push(Instance::new(inst.m, inst.requests[..inst.n() / 2].to_vec()));
                out.push(Instance::new(inst.m, inst.requests[1..].to_vec()));
            }
            out
        }),
    }
}

fn run_policy(inst: &Instance, sched: &mut dyn Scheduler, seed: u64) -> kvsched::metrics::SimOutcome {
    discrete::simulate_cfg(inst, sched, &Predictor::exact(), seed, SimConfig::default())
}

#[test]
fn prop_mcsf_memory_safety_and_completion() {
    forall_cases(0xA11CE, 60, gen_instance(24, 40), |inst| {
        let out = run_policy(inst, &mut McSf::default(), 1);
        if !out.finished {
            return Err("MC-SF did not finish".into());
        }
        if out.max_mem() > inst.m {
            return Err(format!("memory {} > M {}", out.max_mem(), inst.m));
        }
        if out.overflow_events != 0 {
            return Err("MC-SF overflowed with exact predictions".into());
        }
        if out.per_request.len() != inst.n() {
            return Err("lost requests".into());
        }
        Ok(())
    });
}

#[test]
fn prop_mcsf_memory_safety_with_overpredictions() {
    // Thm 4.3 setting: õ ∈ [o, 2o]. Over-predictions must never overflow
    // (the check is conservative).
    forall_cases(0xB0B, 40, gen_instance(20, 40), |inst| {
        let pred = Predictor::overestimate(2.0, 7);
        let out = discrete::simulate_cfg(
            inst,
            &mut McSf::default(),
            &pred,
            1,
            SimConfig::default(),
        );
        if !out.finished || out.overflow_events != 0 || out.max_mem() > inst.m {
            return Err(format!(
                "overflow={} max_mem={} M={}",
                out.overflow_events,
                out.max_mem(),
                inst.m
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_nonpreemption_latency_decomposition() {
    // Non-preemptive service: completion − start == o for every request
    // under MC-SF/MC-Benchmark (no evictions with exact predictions).
    forall_cases(0xC0DE, 40, gen_instance(20, 40), |inst| {
        for sched in [&mut McSf::default() as &mut dyn Scheduler, &mut McBenchmark::default()] {
            let out = run_policy(inst, sched, 3);
            for rec in &out.per_request {
                let o = inst.requests[rec.id].output_len as f64;
                // start is the batch-formation time of its first round;
                // completion = start + o under unit rounds.
                if (rec.completion - rec.start - o).abs() > 1e-9 {
                    return Err(format!(
                        "request {} served {} rounds, o = {o}",
                        rec.id,
                        rec.completion - rec.start
                    ));
                }
                if rec.start + 1e-9 < inst.requests[rec.id].arrival {
                    return Err("started before arrival".into());
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_feasibility_checker_equals_bruteforce() {
    forall_cases(0xFEA5, 200, usize_in(0, u32::MAX as usize), |&seed| {
        let mut r = Rng::new(seed as u64);
        let m = r.i64_range(8, 60) as u64;
        let k = r.usize_range(0, 12);
        let items: Vec<FeasItem> = (0..k)
            .map(|_| FeasItem {
                base: r.i64_range(1, 12) as u64,
                rem: r.i64_range(1, 12) as u64,
            })
            .collect();
        let mut checker = FeasChecker::new(m, &[]);
        for it in &items {
            checker.add(*it);
        }
        if checker.feasible() != feasible_bruteforce(m, &items) {
            return Err(format!("disagreement on m={m} items={items:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_hindsight_below_all_policies_and_above_lower_bound() {
    // OPT(IP) ≤ every online policy; volume bound ≤ OPT. (Small sizes:
    // each MILP solve must stay fast.)
    forall_cases(0x09F7, 8, gen_instance(7, 16), |inst| {
        let cfg = HindsightConfig {
            milp: MilpConfig {
                max_nodes: 3000,
                time_limit: 30.0,
                int_tol: 1e-6,
                objective_integral: true,
            },
            horizon: None,
        };
        let sol = opt::hindsight_optimal(inst, &cfg).map_err(|e| e.to_string())?;
        if !sol.proven_optimal {
            return Ok(()); // don't fail the property on solver limits
        }
        for sched in [
            &mut McSf::default() as &mut dyn Scheduler,
            &mut McBenchmark::default(),
            &mut AlphaProtection::new(0.3, 1.0),
        ] {
            let out = run_policy(inst, sched, 5);
            if !out.finished {
                continue; // clearing loops don't bound OPT
            }
            if sol.total_latency > out.total_latency() + 1e-6 {
                return Err(format!(
                    "OPT {} > {} {}",
                    sol.total_latency,
                    out.algo,
                    out.total_latency()
                ));
            }
        }
        if inst.requests.iter().all(|r| r.arrival == 0.0) {
            let lb = opt::opt_lower_bound(inst);
            if lb > sol.total_latency + 1e-6 {
                return Err(format!("volume bound {lb} > OPT {}", sol.total_latency));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_determinism_across_reruns() {
    forall_cases(0xD37, 20, gen_instance(16, 30), |inst| {
        let a = run_policy(inst, &mut McSf::default(), 42);
        let b = run_policy(inst, &mut McSf::default(), 42);
        if (a.total_latency() - b.total_latency()).abs() > 1e-12 || a.rounds != b.rounds {
            return Err("nondeterministic simulation".into());
        }
        Ok(())
    });
}

#[test]
fn prop_work_conservation_mcsf() {
    // Whenever requests are waiting and the machine has room for the
    // smallest one, MC-SF admits something: total makespan ≤ max_arrival
    // + Σ o_i (no idle rounds with feasible waiting work).
    forall_cases(0x3417, 40, gen_instance(20, 40), |inst| {
        let out = run_policy(inst, &mut McSf::default(), 2);
        let max_a = inst
            .requests
            .iter()
            .map(|r| r.arrival)
            .fold(0.0f64, f64::max);
        let serial: u64 = inst.requests.iter().map(|r| r.output_len).sum();
        if out.makespan() > max_a + serial as f64 {
            return Err(format!(
                "makespan {} exceeds work-conserving bound {}",
                out.makespan(),
                max_a + serial as f64
            ));
        }
        Ok(())
    });
}
