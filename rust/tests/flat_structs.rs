//! Property tests for the flat hot-path structures introduced by the
//! event-driven core work: the [`kvsched::util::slab::Slab`] arena and
//! the bucketed waiting index inside
//! [`kvsched::sched::incremental::IncrementalCore`].
//!
//! The in-module unit tests cover small cases; these model-based tests
//! drive the structures at scales the hot path actually sees — in
//! particular waiting queues several times larger than one bucket, so
//! bucket splits, mid-bucket removals and bucket releases all fire.

use kvsched::core::{ActiveReq, QueuedReq};
use kvsched::sched::feasibility::{admit_greedy_lazy, OrdF64};
use kvsched::sched::incremental::IncrementalCore;
use kvsched::util::prop::{forall_cases, usize_in};
use kvsched::util::rng::Rng;
use kvsched::util::slab::Slab;
use std::collections::BTreeMap;

/// The satellite invariant: slot recycling must never hand out an index
/// that still holds a live entry, and live entries must never be
/// disturbed by unrelated insert/remove traffic. Model: a `BTreeMap`
/// from slot to expected value, updated in lockstep with the slab under
/// a random op sequence.
#[test]
fn slab_recycling_never_aliases_live_entries() {
    forall_cases(0x51AB, 200, usize_in(0, u32::MAX as usize), |&seed| {
        let mut rng = Rng::new(seed as u64);
        let mut slab: Slab<u64> = Slab::new();
        let mut live: BTreeMap<usize, u64> = BTreeMap::new();
        let mut stamp = 0u64;
        let steps = rng.usize_range(1, 250);
        for step in 0..steps {
            if live.is_empty() || rng.bool(0.55) {
                let slot = slab.insert(stamp);
                if live.contains_key(&slot) {
                    return Err(format!(
                        "step {step}: insert handed out slot {slot} still holding {:?}",
                        live.get(&slot)
                    ));
                }
                live.insert(slot, stamp);
                stamp += 1;
            } else {
                let victims: Vec<usize> = live.keys().copied().collect();
                let slot = victims[rng.usize_range(0, victims.len() - 1)];
                let expect = live.remove(&slot);
                if slab.remove(slot) != expect {
                    return Err(format!("step {step}: remove({slot}) lost {expect:?}"));
                }
                if slab.get(slot).is_some() {
                    return Err(format!("step {step}: slot {slot} live after removal"));
                }
            }
            // Every live entry is intact, every dead slot vacant.
            if slab.len() != live.len() {
                return Err(format!("step {step}: len {} != model {}", slab.len(), live.len()));
            }
            for (&slot, &v) in &live {
                if slab.get(slot) != Some(&v) {
                    return Err(format!(
                        "step {step}: slot {slot} holds {:?}, expected {v}",
                        slab.get(slot)
                    ));
                }
            }
        }
        let walked: Vec<(usize, u64)> = slab.iter().map(|(i, &v)| (i, v)).collect();
        let expect: Vec<(usize, u64)> = live.into_iter().collect();
        if walked != expect {
            return Err(format!("final iter {walked:?} != model {expect:?}"));
        }
        Ok(())
    });
}

/// Multi-bucket churn: burst arrivals push the waiting index far past
/// one bucket capacity (64), then partial admissions remove runs from
/// the middle of buckets, completions and evictions churn the batch —
/// and every admission scan must still match the from-scratch snapshot
/// oracle exactly. (The in-module incremental tests never exceed ~30
/// waiting requests, so splits are exercised only here.)
#[test]
fn bucketed_wait_index_matches_snapshot_at_split_scale() {
    forall_cases(0xB0C3, 25, usize_in(0, u32::MAX as usize), |&seed| {
        let mut rng = Rng::new(seed as u64);
        let m = rng.i64_range(40, 120) as u64;
        let mut core = IncrementalCore::default();
        let mut waiting: Vec<QueuedReq> = Vec::new();
        // Mirror running set: (id, s, o_true, pred, started_round).
        let mut running: Vec<(usize, u64, u64, u64, u64)> = Vec::new();
        let mut next_id = 0usize;
        let mut peak_waiting = 0usize;
        for now in 1..=60u64 {
            for _ in 0..rng.usize_range(0, 14) {
                let q = QueuedReq {
                    id: next_id,
                    arrival: now as f64,
                    s: rng.i64_range(1, 4) as u64,
                    pred: rng.i64_range(1, 8) as u64,
                    class: 0,
                };
                core.on_arrival(0, q.pred, &q);
                waiting.push(q);
                next_id += 1;
            }
            peak_waiting = peak_waiting.max(waiting.len());
            let active: Vec<ActiveReq> = running
                .iter()
                .map(|&(id, s, _o, pred, r0)| ActiveReq {
                    id,
                    s,
                    done: now - r0,
                    pred_total: pred,
                    started_round: r0,
                })
                .collect();
            let snap = admit_greedy_lazy(
                m,
                &active,
                &waiting,
                |c| (c.pred, OrdF64(c.arrival), c.id),
                true,
            );
            let inc = core.admit(now, m, true);
            if inc != snap {
                return Err(format!("round {now}: inc {inc:?} != snap {snap:?}"));
            }
            for &id in &inc {
                let pos = waiting.iter().position(|w| w.id == id).unwrap();
                let w = waiting.remove(pos);
                let o_true = (w.pred as i64 + rng.i64_range(-2, 2)).max(1) as u64;
                running.push((id, w.s, o_true, w.pred, now));
            }
            let mut evict_one = rng.bool(0.2) && running.len() > 1;
            running.retain(|&(id, s, o, pred, r0)| {
                if now - r0 + 1 >= o {
                    core.on_complete(id);
                    false
                } else if evict_one {
                    evict_one = false;
                    let q = QueuedReq {
                        id,
                        arrival: r0 as f64,
                        s,
                        pred,
                        class: 0,
                    };
                    core.on_evict(0, q.pred, &q);
                    waiting.push(q);
                    false
                } else {
                    true
                }
            });
        }
        // The scenario only proves something about bucket machinery if
        // the index actually outgrew a single bucket.
        if peak_waiting <= 64 {
            return Err(format!(
                "generator too tame: peak waiting {peak_waiting} never split a bucket"
            ));
        }
        Ok(())
    });
}
