//! Differential property test for the incremental scheduling core: for
//! every policy and workload, the event-driven O(Δ)-per-round engine
//! path (`SimConfig { incremental: true }`) must produce a `SimOutcome`
//! **bit-identical** to the legacy per-round snapshot path — same admit
//! order, same per-request completions, same memory/overflow/eviction
//! counters, same round count — across ≥200 random instances, with both
//! exact and noisy predictions (the noisy runs drive the overflow /
//! `on_evict` hooks).

use kvsched::core::{Instance, Request};
use kvsched::metrics::SimOutcome;
use kvsched::predictor::Predictor;
use kvsched::sched::{by_name, Scheduler};
use kvsched::sim::engine::run;
use kvsched::sim::SimConfig;
use kvsched::util::prop::{forall_cases, usize_in};
use kvsched::util::rng::Rng;
use kvsched::workload::synthetic;

/// Policies under test: incremental implementations (MC-SF variants,
/// MC-Benchmark, and the priority-weighted P-MC-SF) plus snapshot-only
/// baselines, which must be unaffected by the engine flag. `priority` /
/// `edf` run untiered here (uniform ranks / no deadlines) — the classed
/// differential lives in tests/slo_reduction.rs.
const SPECS: [&str; 9] = [
    "mcsf",
    "mcsf:alpha=0.15",
    "mcsf:skip=1",
    "mc-benchmark",
    "protect:alpha=0.2",
    "protect:alpha=0.1,beta=0.5",
    "fcfs:threshold=0.9",
    "priority",
    "edf:threshold=0.9",
];

fn cfg(incremental: bool) -> SimConfig {
    SimConfig {
        // Bounded caps so clearing livelocks (small-α on uniform loads)
        // terminate quickly; both paths share the caps, so truncated
        // runs must match bit-for-bit too.
        max_rounds: 10_000,
        stall_rounds: 1_500,
        record_series: true,
        incremental,
        ..SimConfig::default()
    }
}

fn assert_identical(a: &SimOutcome, b: &SimOutcome, ctx: &str) {
    assert_eq!(a.algo, b.algo, "{ctx}: algo");
    assert_eq!(a.finished, b.finished, "{ctx}: finished");
    assert_eq!(a.rounds, b.rounds, "{ctx}: rounds");
    assert_eq!(a.peak_mem, b.peak_mem, "{ctx}: peak_mem");
    assert_eq!(a.overflow_events, b.overflow_events, "{ctx}: overflows");
    assert_eq!(a.evicted_requests, b.evicted_requests, "{ctx}: evictions");
    assert_eq!(a.per_request, b.per_request, "{ctx}: per-request records");
    assert_eq!(a.mem_series, b.mem_series, "{ctx}: memory series");
    assert_eq!(a.tokens_series, b.tokens_series, "{ctx}: token series");
    assert_eq!(
        a.total_latency().to_bits(),
        b.total_latency().to_bits(),
        "{ctx}: total latency bits"
    );
}

fn diff_instance(inst: &Instance, case: &str) -> Result<(), String> {
    for spec in SPECS {
        for (pname, pred) in [
            ("exact", Predictor::exact()),
            ("noisy", Predictor::uniform_noise(0.5, 11)),
        ] {
            let mut s1: Box<dyn Scheduler> = by_name(spec).unwrap();
            let mut s2: Box<dyn Scheduler> = by_name(spec).unwrap();
            let ctx = format!("{case} spec={spec} pred={pname}");
            let inc = run(inst, s1.as_mut(), &pred, &kvsched::perf::UnitTime, 9, cfg(true))
                .map_err(|e| format!("{ctx}: incremental failed: {e}"))?;
            let snap = run(inst, s2.as_mut(), &pred, &kvsched::perf::UnitTime, 9, cfg(false))
                .map_err(|e| format!("{ctx}: snapshot failed: {e}"))?;
            assert_identical(&inc, &snap, &ctx);
        }
    }
    Ok(())
}

/// 120 fully random small instances via the in-repo property framework.
#[test]
fn incremental_equals_snapshot_on_random_instances() {
    forall_cases(0x1DE17, 120, usize_in(0, u32::MAX as usize), |&seed| {
        let mut rng = Rng::new(seed as u64);
        let m = rng.i64_range(8, 50) as u64;
        let n = rng.usize_range(1, 30);
        let reqs: Vec<Request> = (0..n)
            .map(|i| {
                let s = rng.i64_range(1, 5) as u64;
                let o = rng.i64_range(1, (m - s).min(14) as i64) as u64;
                let a = rng.i64_range(0, 8) as f64;
                Request::new(i, a, s, o)
            })
            .collect();
        diff_instance(&Instance::new(m, reqs), &format!("seed={seed:#x}"))
    });
}

/// 40 + 40 instances from the paper's §5.1 synthetic arrival models.
#[test]
fn incremental_equals_snapshot_on_paper_arrival_models() {
    let mut rng = Rng::new(0xA221);
    for trial in 0..40 {
        let inst = synthetic::arrival_model_1(&mut rng);
        diff_instance(&inst, &format!("model1 trial={trial}")).unwrap();
    }
    for trial in 0..40 {
        let inst = synthetic::arrival_model_2(&mut rng);
        diff_instance(&inst, &format!("model2 trial={trial}")).unwrap();
    }
}

/// The Thm-4.1 adversarial construction: long-request head-of-line
/// pressure with a burst release — a shape the random generators rarely
/// hit.
#[test]
fn incremental_equals_snapshot_on_adversarial_instances() {
    for m in [16u64, 64, 144] {
        let inst = synthetic::adversarial_thm41(m, 0);
        diff_instance(&inst, &format!("thm41 m={m}")).unwrap();
    }
}
