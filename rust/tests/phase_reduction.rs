//! Phase-split reduction differentials: the prefill/decode lifecycle
//! must be invisible at its neutral configuration and law-abiding away
//! from it.
//!
//! * **Neutral reduction** — `prefill_chunk = 0` (monolithic, the
//!   default every pre-split test still runs under) and an *infinite*
//!   chunk (larger than any prompt) must produce **bit-identical**
//!   `SimOutcome`s: same per-request records, series, counters, and
//!   latency bits. Checked over the same corpus as
//!   `tests/incremental_diff.rs` (random instances, §5.1 arrival
//!   models, Thm-4.1 adversarial), across both engines, single-worker
//!   and 1-worker fleets behind every router.
//! * **Engine agreement under chunking** — finite chunks are new
//!   arithmetic, so round vs event must stay bit-identical there too.
//! * **Disagg reduction** — a 1-prefill + 1-decode fleet with zero
//!   KV-transfer cost on serially spaced arrivals reduces to the
//!   homogeneous single worker, record for record.
//! * **Chunk laws** — prefill work sums to exactly the prompt length
//!   (no token lost or double-prefilled), per-iteration prefill work
//!   never exceeds the chunk, serial TTFT is exactly `ceil(s/c)` unit
//!   rounds, and under a prefill-cost-proportional clock an interactive
//!   request's TTFT never *decreases* as the chunk grows — shrinking
//!   the chunk is what buys TTFT protection.

use std::sync::Mutex;

use kvsched::cluster::Fleet;
use kvsched::core::{DisaggSpec, FleetSpec, Instance, Request};
use kvsched::metrics::SimOutcome;
use kvsched::perf::{BatchComposition, PerfModel, UnitTime};
use kvsched::predictor::Predictor;
use kvsched::sched::by_name;
use kvsched::sim::engine::run;
use kvsched::sim::{run_fleet_disagg, EngineKind, SimConfig};
use kvsched::util::prop::{forall_cases, usize_in};
use kvsched::util::rng::Rng;
use kvsched::workload::synthetic;

/// Larger than any prompt in the corpus: every prefill completes in its
/// admission round, exactly like the monolithic path.
const INF_CHUNK: u64 = 1 << 32;

/// Every registered router, including the two disagg-tier policies.
const ROUTERS: [&str; 7] = [
    "rr",
    "jsq",
    "least-kv",
    "po2",
    "slo-aware",
    "prefill-balance",
    "kv-headroom",
];

/// Incremental implementations plus snapshot-only baselines — the
/// `incremental_diff` mix trimmed for the extra chunk/engine axes.
const SPECS: [&str; 4] = [
    "mcsf",
    "mc-benchmark",
    "protect:alpha=0.1,beta=0.5",
    "fcfs:threshold=0.9",
];

fn cfg(engine: EngineKind, chunk: u64) -> SimConfig {
    SimConfig {
        max_rounds: 10_000,
        stall_rounds: 1_500,
        record_series: true,
        incremental: true,
        engine,
        prefill_chunk: chunk,
    }
}

fn assert_identical(a: &SimOutcome, b: &SimOutcome, ctx: &str) {
    assert_eq!(a.algo, b.algo, "{ctx}: algo");
    assert_eq!(a.assigned, b.assigned, "{ctx}: assigned");
    assert_eq!(a.finished, b.finished, "{ctx}: finished");
    assert_eq!(a.rounds, b.rounds, "{ctx}: rounds");
    assert_eq!(a.peak_mem, b.peak_mem, "{ctx}: peak_mem");
    assert_eq!(a.overflow_events, b.overflow_events, "{ctx}: overflows");
    assert_eq!(a.evicted_requests, b.evicted_requests, "{ctx}: evictions");
    assert_eq!(a.per_request, b.per_request, "{ctx}: per-request records");
    assert_eq!(a.mem_series, b.mem_series, "{ctx}: memory series");
    assert_eq!(a.tokens_series, b.tokens_series, "{ctx}: token series");
    assert_eq!(
        a.total_latency().to_bits(),
        b.total_latency().to_bits(),
        "{ctx}: total latency bits"
    );
}

/// The incremental_diff random-instance generator, shared across the
/// corpus tests below.
fn random_instance(seed: u64) -> Instance {
    let mut rng = Rng::new(seed);
    let m = rng.i64_range(8, 50) as u64;
    let n = rng.usize_range(1, 30);
    let reqs: Vec<Request> = (0..n)
        .map(|i| {
            let s = rng.i64_range(1, 5) as u64;
            let o = rng.i64_range(1, (m - s).min(14) as i64) as u64;
            let a = rng.i64_range(0, 8) as f64;
            Request::new(i, a, s, o)
        })
        .collect();
    Instance::new(m, reqs)
}

/// Monolithic (`chunk = 0`) vs infinite chunk, every spec × predictor ×
/// engine: bit-identical.
fn diff_neutral(inst: &Instance, case: &str) -> Result<(), String> {
    for spec in SPECS {
        for (pname, pred) in [
            ("exact", Predictor::exact()),
            ("noisy", Predictor::uniform_noise(0.5, 11)),
        ] {
            for engine in [EngineKind::Round, EngineKind::Event] {
                let ctx = format!("{case} spec={spec} pred={pname} engine={engine}");
                let mut s1 = by_name(spec).unwrap();
                let mono = run(inst, s1.as_mut(), &pred, &UnitTime, 9, cfg(engine, 0))
                    .map_err(|e| format!("{ctx}: monolithic failed: {e}"))?;
                let mut s2 = by_name(spec).unwrap();
                let inf = run(inst, s2.as_mut(), &pred, &UnitTime, 9, cfg(engine, INF_CHUNK))
                    .map_err(|e| format!("{ctx}: infinite-chunk failed: {e}"))?;
                assert_identical(&mono, &inf, &ctx);
            }
        }
    }
    Ok(())
}

/// 60 random instances: the zero-cost-prefill reduction on both engines.
#[test]
fn monolithic_equals_infinite_chunk_on_random_instances() {
    forall_cases(0x9A5E, 60, usize_in(0, u32::MAX as usize), |&seed| {
        diff_neutral(&random_instance(seed as u64), &format!("seed={seed:#x}"))
    });
}

/// The §5.1 arrival models and the Thm-4.1 adversarial family.
#[test]
fn monolithic_equals_infinite_chunk_on_paper_models() {
    let mut rng = Rng::new(0xA221);
    for trial in 0..15 {
        let inst = synthetic::arrival_model_1(&mut rng);
        diff_neutral(&inst, &format!("model1 trial={trial}")).unwrap();
    }
    for trial in 0..15 {
        let inst = synthetic::arrival_model_2(&mut rng);
        diff_neutral(&inst, &format!("model2 trial={trial}")).unwrap();
    }
    for m in [16u64, 64, 144] {
        let inst = synthetic::adversarial_thm41(m, 0);
        diff_neutral(&inst, &format!("thm41 m={m}")).unwrap();
    }
}

/// A 1-worker fleet behind every router keeps the reduction under
/// chunking: fleet(chunk) ≡ engine(chunk) for monolithic, finite, and
/// infinite chunks, on both engines.
#[test]
fn one_worker_fleet_matches_engine_under_chunking() {
    forall_cases(0xC4A2, 25, usize_in(0, u32::MAX as usize), |&seed| {
        let inst = random_instance(seed as u64);
        for chunk in [0u64, 3, INF_CHUNK] {
            for engine in [EngineKind::Round, EngineKind::Event] {
                let mut single = by_name("mcsf").unwrap();
                let base = run(
                    &inst,
                    single.as_mut(),
                    &Predictor::exact(),
                    &UnitTime,
                    9,
                    cfg(engine, chunk),
                )
                .map_err(|e| format!("seed={seed:#x} chunk={chunk}: engine failed: {e}"))?;
                for router in ROUTERS {
                    let ctx =
                        format!("seed={seed:#x} chunk={chunk} engine={engine} router={router}");
                    let mut fleet = Fleet::new(FleetSpec::single(), "mcsf", router).unwrap();
                    let out = fleet
                        .try_simulate(&inst, &Predictor::exact(), &UnitTime, 9, cfg(engine, chunk))
                        .map_err(|e| format!("{ctx}: fleet failed: {e}"))?;
                    assert_identical(&base, &out.per_worker[0], &ctx);
                }
            }
        }
        Ok(())
    });
}

/// Finite chunks are new arithmetic — round and event engines must agree
/// bit for bit there too, across the random corpus.
#[test]
fn chunked_runs_identical_across_engines() {
    forall_cases(0xE7E4, 40, usize_in(0, u32::MAX as usize), |&seed| {
        let inst = random_instance(seed as u64);
        for spec in SPECS {
            for chunk in [1u64, 2, 7] {
                let ctx = format!("seed={seed:#x} spec={spec} chunk={chunk}");
                let mut s1 = by_name(spec).unwrap();
                let round = run(
                    &inst,
                    s1.as_mut(),
                    &Predictor::exact(),
                    &UnitTime,
                    9,
                    cfg(EngineKind::Round, chunk),
                )
                .map_err(|e| format!("{ctx}: round failed: {e}"))?;
                let mut s2 = by_name(spec).unwrap();
                let event = run(
                    &inst,
                    s2.as_mut(),
                    &Predictor::exact(),
                    &UnitTime,
                    9,
                    cfg(EngineKind::Event, chunk),
                )
                .map_err(|e| format!("{ctx}: event failed: {e}"))?;
                assert_identical(&round, &event, &ctx);
            }
        }
        Ok(())
    });
}

/// Serially spaced random instance: request i arrives only after request
/// i−1 has had time to fully complete anywhere (prefill + transfer +
/// decode), so no tier ever queues.
fn serial_instance(seed: u64) -> Instance {
    let mut rng = Rng::new(seed);
    let m = rng.i64_range(30, 80) as u64;
    let n = rng.usize_range(1, 12);
    let mut t = 0.0f64;
    let reqs: Vec<Request> = (0..n)
        .map(|i| {
            let s = rng.i64_range(1, 8) as u64;
            let o = rng.i64_range(1, 12) as u64;
            let r = Request::new(i, t, s, o);
            // Unit-time worst case even at chunk = 1: ceil(s/1) + o − 1
            // rounds of service, plus slack.
            t += (s + o + 4) as f64;
            r
        })
        .collect();
    Instance::new(m, reqs)
}

/// The acceptance-criteria reduction: a disagg fleet with zero
/// KV-transfer cost and identical workers reduces to the homogeneous
/// engine — stitched per-request records bit-identical, corpus-scale,
/// both engines.
#[test]
fn disagg_zero_transfer_reduces_to_homogeneous() {
    forall_cases(0xD15A, 40, usize_in(0, u32::MAX as usize), |&seed| {
        let inst = serial_instance(seed as u64);
        for engine in [EngineKind::Round, EngineKind::Event] {
            let ctx = format!("seed={seed:#x} engine={engine}");
            let mut single = by_name("mcsf").unwrap();
            let base = run(
                &inst,
                single.as_mut(),
                &Predictor::exact(),
                &UnitTime,
                9,
                cfg(engine, 0),
            )
            .map_err(|e| format!("{ctx}: engine failed: {e}"))?;
            let mut scheds: Vec<_> = (0..2).map(|_| by_name("mcsf").unwrap()).collect();
            let out = run_fleet_disagg(
                &inst,
                &mut scheds,
                DisaggSpec::default(),
                None,
                &Predictor::exact(),
                &UnitTime,
                9,
                cfg(engine, 0),
            )
            .map_err(|e| format!("{ctx}: disagg failed: {e}"))?;
            assert!(out.finished(), "{ctx}");
            assert_eq!(out.unserved(), 0, "{ctx}");
            let mut recs: Vec<_> = out
                .per_worker
                .iter()
                .flat_map(|w| w.per_request.iter().cloned())
                .collect();
            recs.sort_by_key(|r| r.id);
            assert_eq!(recs, base.per_request, "{ctx}: stitched records");
            assert_eq!(
                out.total_latency().to_bits(),
                base.total_latency().to_bits(),
                "{ctx}: total latency bits"
            );
        }
        Ok(())
    });
}

/// Unit-clock perf model that remembers every batch it timed, for
/// auditing the engine's prefill accounting from the outside.
struct CountingPerf(Mutex<Vec<BatchComposition>>);

impl CountingPerf {
    fn new() -> CountingPerf {
        CountingPerf(Mutex::new(Vec::new()))
    }
}

impl PerfModel for CountingPerf {
    fn name(&self) -> String {
        "counting-unit".into()
    }

    fn iteration_time(&self, batch: &BatchComposition) -> f64 {
        self.0.lock().unwrap().push(*batch);
        1.0
    }
}

/// Chunk accounting: across a run with no evictions, the prefill tokens
/// the perf model is billed for sum to exactly the instance's total
/// prompt length — every chunk size, no token lost or double-prefilled —
/// and (serial instances, so one request in flight) no iteration is
/// billed more than one chunk.
#[test]
fn chunk_accounting_sums_to_prompt_length() {
    forall_cases(0xACC7, 30, usize_in(0, u32::MAX as usize), |&seed| {
        let inst = serial_instance(seed as u64);
        let total = inst.total_prompt_tokens();
        for chunk in [1u64, 2, 3, 5, INF_CHUNK] {
            let ctx = format!("seed={seed:#x} chunk={chunk}");
            let perf = CountingPerf::new();
            let mut sched = by_name("mcsf").unwrap();
            let out = run(
                &inst,
                sched.as_mut(),
                &Predictor::exact(),
                &perf,
                9,
                cfg(EngineKind::Round, chunk),
            )
            .map_err(|e| format!("{ctx}: run failed: {e}"))?;
            assert!(out.finished(), "{ctx}");
            assert_eq!(out.evicted_requests, 0, "{ctx}: accounting needs no evictions");
            let batches = perf.0.lock().unwrap();
            let billed: u64 = batches.iter().map(|b| b.prefill_tokens).sum();
            assert_eq!(billed, total, "{ctx}: prefill billing must sum to Σ s_i");
            let max = batches.iter().map(|b| b.prefill_tokens).max().unwrap_or(0);
            assert!(
                max <= chunk,
                "{ctx}: iteration billed {max} prefill tokens > chunk"
            );
        }
        Ok(())
    });
}

/// Serial unit-time TTFT is exactly `ceil(s / chunk)` rounds (the last
/// chunk's round piggybacks the first decode token), so TTFT is weakly
/// *decreasing* in the chunk size for the request that owns the prompt.
#[test]
fn serial_ttft_is_ceil_s_over_chunk() {
    let s = 12u64;
    let inst = Instance::new(40, vec![Request::new(0, 0.0, s, 3)]);
    let mut prev = f64::INFINITY;
    for chunk in [1u64, 2, 3, 4, 5, 6, 12, INF_CHUNK, 0] {
        let mut sched = by_name("mcsf").unwrap();
        let out = run(
            &inst,
            sched.as_mut(),
            &Predictor::exact(),
            &UnitTime,
            9,
            cfg(EngineKind::Round, chunk),
        )
        .unwrap();
        let ttft = out.per_request[0].ttft();
        let expect = if chunk == 0 { 1 } else { s.div_ceil(chunk) };
        assert_eq!(ttft, expect as f64, "chunk={chunk}");
        // 0 means monolithic = infinite chunk: keep it last so the
        // monotone sweep stays valid.
        assert!(ttft <= prev, "chunk={chunk}: TTFT must not rise with chunk");
        prev = ttft;
        // The decode phase is untouched by chunking: o − 1 rounds after
        // the first token, plus the KV-transfer-free boundary.
        assert_eq!(out.per_request[0].decode_time(), 2.0, "chunk={chunk}");
    }
}

/// Iteration cost proportional to prefill work — the clock under which
/// chunking matters (UnitTime charges a 1000-token prefill and a
/// 1-token decode identically).
struct PrefillCost;

impl PerfModel for PrefillCost {
    fn name(&self) -> String {
        "prefill-cost".into()
    }

    fn iteration_time(&self, batch: &BatchComposition) -> f64 {
        1.0 + 0.01 * batch.prefill_tokens as f64
    }
}

/// The ISSUE invariant: on a fixed instance — a long prompt hogging the
/// worker plus a short interactive request right behind it — the
/// interactive TTFT never decreases as the chunk size grows. Small
/// chunks bound each iteration's prefill work, letting the short
/// request's first token out early; monolithic prefill makes it wait
/// out the entire long prompt.
#[test]
fn interactive_ttft_never_decreases_as_chunk_grows() {
    let inst = Instance::new(
        1100,
        vec![
            Request::new(0, 0.0, 1000, 5), // batch prompt
            Request::new(1, 0.1, 10, 5),   // interactive
        ],
    );
    let ttft_at = |chunk: u64| {
        let mut sched = by_name("mcsf").unwrap();
        let out = run(
            &inst,
            sched.as_mut(),
            &Predictor::exact(),
            &PrefillCost,
            9,
            cfg(EngineKind::Round, chunk),
        )
        .unwrap();
        out.per_request
            .iter()
            .find(|r| r.id == 1)
            .expect("interactive request completed")
            .ttft()
    };
    // 0 = monolithic, the infinite-chunk limit: last in the sweep.
    let sweep = [25u64, 50, 100, 250, 500, 1000, 0];
    let ttfts: Vec<f64> = sweep.iter().map(|&c| ttft_at(c)).collect();
    for w in ttfts.windows(2) {
        assert!(
            w[1] >= w[0],
            "interactive TTFT decreased as chunk grew: {ttfts:?}"
        );
    }
    assert!(
        *ttfts.last().unwrap() >= 2.0 * ttfts[0],
        "chunked prefill should cut interactive TTFT well below monolithic: {ttfts:?}"
    );
}
