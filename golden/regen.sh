#!/bin/sh
# Regenerate the committed golden traces from the current engine.
# Run from anywhere; commits are left to you (review the diff first).
set -eu
cd "$(dirname "$0")/.."
UPDATE_GOLDEN=1 cargo test --release --test trace_replay golden -- --nocapture
git --no-pager diff --stat -- golden || true
echo "golden fixtures regenerated; review 'git diff golden/' before committing"
