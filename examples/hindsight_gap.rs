//! §5.1 reproduction driver: MC-SF vs the hindsight-optimal IP on
//! synthetic instances under both arrival models, printing the ratio
//! distribution (Figure 2's histograms as text).
//!
//! The paper runs n ∈ [40,60], M ∈ [30,50] with Gurobi; our in-repo
//! branch-and-bound solves the same IP exactly but is slower, so the
//! default scale is reduced (`--scale paper` restores the paper's; see
//! DESIGN.md substitution 1). Shapes are preserved: Model 1 ratios sit
//! at ~1.00x with many exact hits, Model 2 slightly higher.
//!
//! Run: `cargo run --release --example hindsight_gap -- --trials 30`

use kvsched::bench::{fmt, Table};
use kvsched::core::{Instance, Request};
use kvsched::opt::{hindsight_optimal, HindsightConfig};
use kvsched::prelude::*;
use kvsched::sim::discrete;
use kvsched::util::cli::Args;
use kvsched::util::stats;

/// Down-scaled Arrival Model 1 (all requests at t=0).
fn model1_small(rng: &mut Rng) -> Instance {
    let m = rng.i64_range(12, 18) as u64;
    let n = rng.usize_range(6, 9);
    let reqs = (0..n)
        .map(|i| {
            let s = rng.i64_range(1, 3) as u64;
            let o = rng.i64_range(1, (m - s).min(8) as i64) as u64;
            Request::new(i, 0.0, s, o)
        })
        .collect();
    Instance::new(m, reqs)
}

/// Down-scaled Arrival Model 2 (Poisson arrivals over a horizon).
fn model2_small(rng: &mut Rng) -> Instance {
    let m = rng.i64_range(12, 18) as u64;
    let t_max = rng.i64_range(6, 10) as u64;
    let lambda = rng.f64_range(0.5, 1.2);
    let mut reqs = Vec::new();
    for t in 1..=t_max {
        for _ in 0..rng.poisson(lambda) {
            let s = rng.i64_range(1, 3) as u64;
            let o = rng.i64_range(1, (m - s).min(8) as i64) as u64;
            reqs.push(Request::new(reqs.len(), t as f64, s, o));
        }
    }
    if reqs.is_empty() || reqs.len() > 9 {
        return model2_small(rng);
    }
    Instance::new(m, reqs)
}

fn paper_scale_model(model: u8, rng: &mut Rng) -> Instance {
    match model {
        1 => kvsched::workload::synthetic::arrival_model_1(rng),
        _ => kvsched::workload::synthetic::arrival_model_2(rng),
    }
}

fn run_model(name: &str, trials: usize, seed: u64, paper_scale: bool, model: u8) {
    let mut rng = Rng::new(seed);
    let mut ratios = Vec::new();
    let mut exact = 0usize;
    let mut unproven = 0usize;
    for trial in 0..trials {
        let inst = if paper_scale {
            paper_scale_model(model, &mut rng)
        } else if model == 1 {
            model1_small(&mut rng)
        } else {
            model2_small(&mut rng)
        };
        let mut cfg = HindsightConfig::default();
        // Keep the per-instance solver budget small: unproven instances
        // are skipped and counted rather than stalling the sweep.
        cfg.milp.time_limit = 15.0;
        cfg.milp.max_nodes = 2000;
        let sol = match hindsight_optimal(&inst, &cfg) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("trial {trial}: {e}");
                continue;
            }
        };
        if !sol.proven_optimal {
            unproven += 1;
            continue;
        }
        let out = discrete::simulate(&inst, &mut McSf::default(), &Predictor::exact(), 1);
        let ratio = out.total_latency() / sol.total_latency;
        assert!(ratio >= 1.0 - 1e-9, "MC-SF beat a 'proven' optimum?!");
        if ratio < 1.0 + 1e-9 {
            exact += 1;
        }
        ratios.push(ratio);
    }

    println!(
        "\n=== {name}: {} solved trials (exact optimum hit in {exact}; {unproven} unproven skipped) ===",
        ratios.len()
    );
    println!(
        "ratio MC-SF/OPT: avg {:.4}  best {:.4}  worst {:.4}",
        stats::mean(&ratios),
        stats::min(&ratios),
        stats::max(&ratios)
    );
    // Text histogram (Figure 2).
    let (edges, counts) = stats::histogram(&ratios, 1.0, 1.25, 10);
    let maxc = counts.iter().copied().max().unwrap_or(1) as f64;
    let mut table = Table::new(&format!("Figure 2 ({name}): ratio histogram"), &["bin", "count", "bar"]);
    for (e, c) in edges.iter().zip(&counts) {
        table.row(&[
            format!("[{:.3},{:.3})", e, e + 0.025),
            c.to_string(),
            stats::ascii_bar(*c as f64, maxc, 40),
        ]);
    }
    table.print();
    table.save_json(&format!("fig2_{}", name.replace(' ', "_")));
}

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let trials = args.usize_or("trials", 30);
    let seed = args.u64_or("seed", 2026);
    let paper_scale = args.str_or("scale", "small") == "paper";
    let _ = fmt(0.0);
    run_model("Arrival Model 1", trials, seed, paper_scale, 1);
    run_model("Arrival Model 2", trials, seed + 1, paper_scale, 2);
}
