//! **The end-to-end driver**: serve a real (randomly initialized) tiny
//! transformer through the full three-layer stack on a live workload —
//! proving that L3 (rust coordinator + MC-SF), the PJRT runtime, and the
//! L2/L1 AOT artifacts (JAX model + Pallas decode-attention kernel)
//! compose.
//!
//! Pipeline per request: client thread submits prompt bytes with a
//! Poisson arrival gap → MC-SF admits under the KV budget → prefill
//! executable fills the KV cache and emits the first token → decode
//! executable (the Pallas kernel's HLO) generates the rest → reply with
//! tokens + latency.
//!
//! Requires `make artifacts`. Results recorded in EXPERIMENTS.md §E14.
//!
//! Run: `cargo run --release --example serve_e2e -- --n 24 --lambda 4`

use kvsched::bench::{fmt, Table};
use kvsched::coordinator::{Coordinator, CoordinatorConfig, ServeRequest};
use kvsched::prelude::*;
use kvsched::runtime::Engine;
use kvsched::util::cli::Args;
use kvsched::util::stats;
use std::time::Instant;

fn main() -> kvsched::util::error::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let n = args.usize_or("n", 24);
    let lambda = args.f64_or("lambda", 4.0);
    let seed = args.u64_or("seed", 7);
    let algo = args.str_or("algo", "mcsf");
    let dir = args.str_or("artifacts", "artifacts");

    println!("loading + compiling artifacts from {dir}/ ...");
    let t_load = Instant::now();
    let engine = Engine::load(dir)?;
    let model = *engine.model();
    println!(
        "model: {} layers, d={}, {} heads, cache {} tokens/row; \
         decode buckets up to {}; compiled in {:.2}s",
        model.n_layers,
        model.d_model,
        model.n_heads,
        model.max_seq,
        engine.max_decode_batch(),
        t_load.elapsed().as_secs_f64()
    );

    let sched = kvsched::sched::by_name(algo)?;
    let coord = Coordinator::start(engine, sched, CoordinatorConfig::default());

    // Client: submit n requests with Exp(λ) gaps and LMSYS-ish length
    // variety (scaled to the tiny model's cache).
    let mut rng = Rng::new(seed);
    let t0 = Instant::now();
    let mut pending = Vec::new();
    let mut total_requested_tokens = 0u64;
    for i in 0..n {
        let o = rng.usize_range(4, 48) as u64;
        let prompt_len = rng.usize_range(3, 30);
        let prompt: Vec<u8> = (0..prompt_len)
            .map(|_| rng.usize_range(32, 126) as u8)
            .collect();
        total_requested_tokens += o + prompt_len as u64;
        pending.push((i, o, coord.submit(ServeRequest {
            prompt,
            max_new_tokens: o,
            predicted_new_tokens: o,
            class: 0,
        })));
        std::thread::sleep(std::time::Duration::from_secs_f64(rng.exponential(lambda)));
    }
    let submit_span = t0.elapsed().as_secs_f64();

    let mut latencies = Vec::new();
    let mut waits = Vec::new();
    let mut generated = 0u64;
    for (i, o, rx) in pending {
        let reply = rx.recv()?;
        assert_eq!(reply.tokens.len() as u64, o, "request {i} token count");
        generated += reply.tokens.len() as u64;
        latencies.push(reply.latency);
        waits.push(reply.queue_wait);
    }
    let wall = t0.elapsed().as_secs_f64();
    let stats_out = coord.shutdown();

    let mut table = Table::new("serve_e2e results", &["metric", "value"]);
    table.row(&["requests".into(), n.to_string()]);
    table.row(&["arrival span (s)".into(), fmt(submit_span)]);
    table.row(&["wall time (s)".into(), fmt(wall)]);
    table.row(&["output tokens".into(), generated.to_string()]);
    table.row(&["tokens/s (gen)".into(), fmt(generated as f64 / wall)]);
    table.row(&["req tokens (in+out)".into(), total_requested_tokens.to_string()]);
    table.row(&["avg latency (s)".into(), fmt(stats::mean(&latencies))]);
    table.row(&["p50 latency (s)".into(), fmt(stats::median(&latencies))]);
    table.row(&["p95 latency (s)".into(), fmt(stats::percentile(&latencies, 95.0))]);
    table.row(&["avg queue wait (s)".into(), fmt(stats::mean(&waits))]);
    table.row(&["scheduler rounds".into(), stats_out.rounds.to_string()]);
    table.row(&["peak KV tokens".into(), stats_out.max_mem().to_string()]);
    table.print();
    table.save_json("serve_e2e");
    println!("\nall layers composed: JAX/Pallas AOT artifacts served by the rust coordinator.");
    Ok(())
}
