//! Quickstart: generate an LMSYS-like workload, run MC-SF against the
//! paper's baselines on the Llama2-70B/2×A100 performance model, and
//! print the comparison — the 60-second tour of the public API.
//!
//! Run: `cargo run --release --example quickstart [-- --n 400 --lambda 50]`

use kvsched::bench::{fmt, Table};
use kvsched::perf::Llama70bA100x2;
use kvsched::prelude::*;
use kvsched::sim::{continuous, SimConfig};
use kvsched::util::cli::Args;
use kvsched::workload::lmsys::LmsysGen;

fn main() -> kvsched::util::error::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let n = args.usize_or("n", 1000);
    let lambda = args.f64_or("lambda", 50.0);
    let seed = args.u64_or("seed", 1);

    // 1. A workload: n requests with LMSYS-calibrated lengths arriving
    //    as a Poisson process, served under the paper's KV budget.
    let gen = LmsysGen::default();
    let mut rng = Rng::new(seed);
    let inst = gen.instance(n, lambda, continuous::PAPER_M, &mut rng);
    println!(
        "workload: {} requests, λ={lambda}/s, M={} KV tokens",
        inst.n(),
        inst.m
    );

    // 2. The serving simulation: per-iteration latency from the
    //    analytic Llama2-70B on 2×A100 model (the paper's Vidur role).
    let perf = Llama70bA100x2::default();

    // 3. Compare MC-SF with the §5.2 baselines.
    let mut table = Table::new(
        "MC-SF vs baselines (avg end-to-end latency)",
        &["algorithm", "avg_s", "p50_s", "p95_s", "clearings", "finished"],
    );
    for mut sched in kvsched::sched::paper_benchmark_suite() {
        let out = continuous::try_simulate(
            &inst,
            sched.as_mut(),
            &Predictor::exact(),
            &perf,
            seed,
            SimConfig::default(),
        )?;
        let s = out.summary();
        table.row(&[
            out.algo.clone(),
            fmt(out.avg_latency()),
            fmt(s.p50),
            fmt(s.p95),
            out.overflow_events.to_string(),
            out.finished.to_string(),
        ]);
    }
    table.print();
    table.save_json("quickstart");
    println!("\n(rows also saved to results/quickstart.json)");
    Ok(())
}
