//! §5.2 reproduction driver at adjustable scale: the high- and
//! low-demand serving experiments on the LMSYS-calibrated workload with
//! the Llama2-70B/2×A100 performance model — the pipeline behind
//! Figures 3, 4, 8 and 11 (the figure benches sweep it; this example is
//! the single-run, human-readable version).
//!
//! Run: `cargo run --release --example lmsys_replay -- --n 1000`

use kvsched::bench::{fmt, Table};
use kvsched::perf::Llama70bA100x2;
use kvsched::prelude::*;
use kvsched::sim::{continuous, SimConfig};
use kvsched::util::cli::Args;
use kvsched::workload::lmsys::LmsysGen;

fn main() -> kvsched::util::error::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let n = args.usize_or("n", 1000);
    let seed = args.u64_or("seed", 3);

    for (name, lambda) in [("high demand (λ=50)", 50.0), ("low demand (λ=10)", 10.0)] {
        let gen = LmsysGen::default();
        let mut rng = Rng::new(seed);
        let inst = gen.instance(n, lambda, continuous::PAPER_M, &mut rng);

        let mut table = Table::new(
            &format!("{name}: {} requests, M = {}", inst.n(), inst.m),
            &["algorithm", "avg_latency_s", "max_mem", "clearings", "finished"],
        );
        let perf = Llama70bA100x2::default();
        for mut sched in kvsched::sched::paper_benchmark_suite() {
            let out = continuous::try_simulate(
                &inst,
                sched.as_mut(),
                &Predictor::exact(),
                &perf,
                seed,
                SimConfig {
                    max_rounds: 500_000,
                    record_series: false,
                    ..SimConfig::default()
                },
            )?;
            table.row(&[
                out.algo.clone(),
                fmt(out.avg_latency()),
                out.max_mem().to_string(),
                out.overflow_events.to_string(),
                out.finished.to_string(),
            ]);
        }
        table.print();
        table.save_json(&format!(
            "lmsys_replay_{}",
            if lambda > 20.0 { "high" } else { "low" }
        ));
    }
    Ok(())
}
